//! The pipeline: composed stages, validated ordering, one `run` from
//! raw weights to a reported, servable artifact.

use super::executor::PipelineExecutor;
use super::recipe::{LccSpec, PruneSpec, QuantSpec, Recipe, ShareSpec, StageSpec};
use super::report::CompressionReport;
use super::stage::Stage;
use super::state::ModelState;
use crate::config::{ExecConfig, ShardSpec};
use crate::graph::AdderGraph;
use crate::lcc::LccConfig;
use crate::metrics::Metrics;
use crate::nn::compressed::Layer1;
use crate::quant::{matrix_csd_adders, FixedPointFormat};
use crate::share::SharedLcc;
use crate::tensor::Matrix;
use anyhow::{bail, Context, Result};

/// A stage as the pipeline holds it: serializable spec, or an opaque
/// custom implementation.
enum Composed {
    Spec(StageSpec),
    Custom(Box<dyn Stage>),
}

impl Composed {
    fn name(&self) -> &'static str {
        match self {
            Composed::Spec(s) => s.kind(),
            Composed::Custom(b) => b.name(),
        }
    }
}

/// A validated, runnable composition of compression stages.
///
/// Build one from a serializable [`Recipe`] (the deployment path) or
/// with [`Pipeline::builder`] (the API path, which also accepts custom
/// [`Stage`] implementations). Running a pipeline never mutates it, so
/// one pipeline can compress many checkpoints.
///
/// ```
/// use lccnn::compress::{demo_weights, Pipeline, Recipe};
/// use lccnn::exec::Executor;
///
/// let pipeline = Pipeline::from_recipe(&Recipe::default()).unwrap();
/// let model = pipeline.run(&demo_weights(16, 3, 4, 0)).unwrap();
/// // the report carries the paper's accounting; the executor serves it
/// assert!(model.report().final_ratio() > 1.0);
/// let y = model.executor().execute_one(&[1.0; 15]);
/// assert_eq!(y.len(), 16);
/// ```
pub struct Pipeline {
    stages: Vec<Composed>,
    exec: ExecConfig,
    /// serve-time sharding of the lowered engine (recipe
    /// `[compress.shard]` or builder `.shard(..)`)
    shard: Option<ShardSpec>,
    /// addition-accounting format (the quantize stage's grid when
    /// present, the paper's default weight format otherwise)
    fmt: FixedPointFormat,
}

impl std::fmt::Debug for Pipeline {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let names: Vec<&str> = self.stages.iter().map(Composed::name).collect();
        f.debug_struct("Pipeline").field("stages", &names).field("exec", &self.exec).finish()
    }
}

fn accounting_fmt(stages: &[Composed]) -> FixedPointFormat {
    for c in stages {
        if let Composed::Spec(StageSpec::Quantize(q)) = c {
            return q.to_format();
        }
    }
    FixedPointFormat::default_weights()
}

/// Ordering contract: at most one of each built-in stage, prune first
/// when present, nothing after LCC. Custom stages may sit anywhere after
/// prune and before LCC.
fn validate(stages: &[Composed]) -> Result<()> {
    let mut seen: Vec<&str> = Vec::new();
    let mut saw_any = false;
    let mut saw_lcc = false;
    for c in stages {
        if saw_lcc {
            bail!("stage {:?} after lcc: lcc lowers the final program and must be last", c.name());
        }
        if let Composed::Spec(spec) = c {
            let kind = c.name();
            if seen.contains(&kind) {
                bail!("duplicate {kind} stage");
            }
            seen.push(kind);
            match spec {
                StageSpec::Prune(_) => {
                    if saw_any {
                        bail!("prune must be the first stage");
                    }
                }
                StageSpec::Lcc(_) => saw_lcc = true,
                _ => {}
            }
        }
        saw_any = true;
    }
    Ok(())
}

impl Pipeline {
    pub fn builder() -> PipelineBuilder {
        PipelineBuilder { stages: Vec::new(), exec: ExecConfig::default(), shard: None }
    }

    /// Instantiate (and validate) the pipeline a recipe describes.
    pub fn from_recipe(recipe: &Recipe) -> Result<Self> {
        let stages: Vec<Composed> = recipe.stages.iter().cloned().map(Composed::Spec).collect();
        validate(&stages)?;
        let fmt = accounting_fmt(&stages);
        Ok(Pipeline { stages, exec: recipe.exec, shard: recipe.shard, fmt })
    }

    /// The serializable recipe reproducing this pipeline — `None` when a
    /// custom stage (not serializable) is composed in.
    pub fn recipe(&self) -> Option<Recipe> {
        let mut stages = Vec::with_capacity(self.stages.len());
        for c in &self.stages {
            match c {
                Composed::Spec(s) => stages.push(s.clone()),
                Composed::Custom(_) => return None,
            }
        }
        Some(Recipe { stages, exec: self.exec, shard: self.shard, ..Recipe::default() })
    }

    pub fn exec_config(&self) -> ExecConfig {
        self.exec
    }

    /// Compress a weight matrix end to end.
    pub fn run(&self, w: &Matrix) -> Result<CompressedModel> {
        self.run_state(ModelState::new(w))
    }

    /// Resume from an existing artifact state — how training-interleaved
    /// coordinators (retraining between stages) hand a mid-pipeline
    /// state to the remaining stages.
    pub fn run_state(&self, mut state: ModelState) -> Result<CompressedModel> {
        let baseline = matrix_csd_adders(state.original(), self.fmt);
        let mut report = CompressionReport::new(state.rows(), state.input_dim(), baseline);
        for c in &self.stages {
            let result = match c {
                Composed::Spec(spec) => spec.to_stage(self.exec).apply(&mut state),
                Composed::Custom(stage) => stage.apply(&mut state),
            };
            result.with_context(|| format!("compress stage {:?}", c.name()))?;
            report.push_stage(c.name(), &state, self.fmt);
        }
        Ok(CompressedModel { state, report, exec: self.exec, shard: self.shard })
    }

    /// [`Pipeline::run`], publishing the report into `metrics`
    /// (`compress.*` series).
    pub fn run_with_metrics(&self, w: &Matrix, metrics: &Metrics) -> Result<CompressedModel> {
        let model = self.run(w)?;
        model.report().publish(metrics);
        Ok(model)
    }
}

/// Builder composing stages in order; [`PipelineBuilder::build`]
/// validates the composition.
pub struct PipelineBuilder {
    stages: Vec<Composed>,
    exec: ExecConfig,
    shard: Option<ShardSpec>,
}

impl PipelineBuilder {
    pub fn prune(self, eps: f32) -> Self {
        self.spec(StageSpec::Prune(PruneSpec { eps }))
    }

    /// Weight sharing with default affinity-propagation parameters.
    pub fn share(self) -> Self {
        self.spec(StageSpec::Share(ShareSpec::default()))
    }

    pub fn share_spec(self, spec: ShareSpec) -> Self {
        self.spec(StageSpec::Share(spec))
    }

    pub fn quantize(self, fmt: FixedPointFormat) -> Self {
        self.spec(StageSpec::Quantize(QuantSpec {
            int_bits: fmt.int_bits,
            frac_bits: fmt.frac_bits,
        }))
    }

    pub fn lcc(self, cfg: &LccConfig) -> Self {
        self.spec(StageSpec::Lcc(LccSpec::from_config(cfg)))
    }

    pub fn lcc_spec(self, spec: LccSpec) -> Self {
        self.spec(StageSpec::Lcc(spec))
    }

    pub fn spec(mut self, spec: StageSpec) -> Self {
        self.stages.push(Composed::Spec(spec));
        self
    }

    /// Compose a custom stage (the resulting pipeline has no
    /// serializable recipe).
    pub fn stage(mut self, stage: Box<dyn Stage>) -> Self {
        self.stages.push(Composed::Custom(stage));
        self
    }

    /// Engine tuning for the lowered graph (and anything a custom stage
    /// reads from the pipeline).
    pub fn exec(mut self, exec: ExecConfig) -> Self {
        self.exec = exec;
        self
    }

    /// Shard the served engine by output ranges (`exec::ShardedExecutor`
    /// over the lowered LCC program; bit-identical to unsharded).
    pub fn shard(mut self, spec: ShardSpec) -> Self {
        self.shard = Some(spec);
        self
    }

    pub fn build(self) -> Result<Pipeline> {
        validate(&self.stages)?;
        let fmt = accounting_fmt(&self.stages);
        Ok(Pipeline { stages: self.stages, exec: self.exec, shard: self.shard, fmt })
    }
}

/// The result of a pipeline run: the final [`ModelState`] plus its
/// [`CompressionReport`] — convertible into a [`Layer1`] (model
/// construction) or a [`PipelineExecutor`] (serving).
pub struct CompressedModel {
    state: ModelState,
    report: CompressionReport,
    exec: ExecConfig,
    shard: Option<ShardSpec>,
}

impl CompressedModel {
    pub fn report(&self) -> &CompressionReport {
        &self.report
    }

    pub fn state(&self) -> &ModelState {
        &self.state
    }

    /// Original column index feeding each compact column.
    pub fn kept(&self) -> &[usize] {
        self.state.kept()
    }

    /// The shared+LCC composition, when an LCC stage ran.
    pub fn lcc(&self) -> Option<&SharedLcc> {
        self.state.lcc()
    }

    /// The lowered shift-add program, when an LCC stage ran.
    pub fn graph(&self) -> Option<&AdderGraph> {
        self.state.lcc().map(SharedLcc::graph)
    }

    pub fn exec_config(&self) -> ExecConfig {
        self.exec
    }

    /// The effective serve-time sharding: the pipeline's explicit spec,
    /// else the engine tuning's `shards` knob ([`ShardSpec::effective`]).
    /// `None` = unsharded.
    pub fn shard_spec(&self) -> Option<ShardSpec> {
        ShardSpec::effective(self.shard, &self.exec)
    }

    /// The layer-1 evaluation strategy (cloning).
    pub fn layer1(&self) -> Layer1 {
        self.state.to_layer1()
    }

    /// Consume into `(kept, Layer1)` without cloning the engine.
    pub fn into_layer1(self) -> (Vec<usize>, Layer1) {
        self.state.into_layer1()
    }

    /// A servable [`crate::exec::Executor`] over the artifact (cloning),
    /// sharded per the pipeline's shard spec.
    pub fn executor(&self) -> PipelineExecutor {
        PipelineExecutor::from_state_sharded(self.state.clone(), self.shard_spec())
    }

    /// Consume into the servable executor without cloning the engine
    /// (the runtime checkpoint-load path).
    pub fn into_executor(self) -> PipelineExecutor {
        let shard = self.shard_spec();
        PipelineExecutor::from_state_sharded(self.state, shard)
    }

    /// A servable executor restricted to the output rows in `range` —
    /// what one remote `shard-worker` serves. Requires an LCC artifact
    /// (the program is cut per output range); requests carry the full
    /// original input dimension, and a gather over range executors is
    /// bit-identical to [`CompressedModel::executor`].
    pub fn range_executor(&self, range: std::ops::Range<usize>) -> Result<PipelineExecutor> {
        PipelineExecutor::from_state_range(self.state.clone(), range)
    }
}

impl std::fmt::Debug for CompressedModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CompressedModel")
            .field("input_dim", &self.state.input_dim())
            .field("rows", &self.state.rows())
            .field("repr", &self.state.repr_name())
            .field("final_additions", &self.report.final_additions())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::demo_weights;

    #[test]
    fn default_recipe_runs_all_three_stages() {
        let w = demo_weights(16, 3, 4, 0);
        let p = Pipeline::from_recipe(&Recipe::default()).unwrap();
        let model = p.run(&w).unwrap();
        let names: Vec<&str> = model.report().stages.iter().map(|s| s.stage.as_str()).collect();
        assert_eq!(names, vec!["prune", "share", "lcc"]);
        assert!(model.graph().is_some());
        assert_eq!(model.kept().len(), 12, "zero columns pruned away");
        // additions decrease along the composed scheme
        let adds: Vec<usize> = model.report().stages.iter().map(|s| s.additions).collect();
        assert!(adds[1] < adds[0], "sharing {} !< dense {}", adds[1], adds[0]);
        assert!(adds[2] < adds[1], "lcc {} !< sharing {}", adds[2], adds[1]);
        assert!(model.report().final_ratio() > 1.0);
    }

    #[test]
    fn builder_matches_recipe_pipeline() {
        let w = demo_weights(16, 3, 4, 1);
        let built = Pipeline::builder()
            .prune(1e-6)
            .share()
            .lcc(&LccConfig::fs())
            .exec(ExecConfig::serial())
            .build()
            .unwrap();
        let recipe = built.recipe().expect("spec-only pipeline serializes");
        let from_recipe = Pipeline::from_recipe(&recipe).unwrap();
        let a = built.run(&w).unwrap();
        let b = from_recipe.run(&w).unwrap();
        assert_eq!(a.report(), b.report());
    }

    #[test]
    fn invalid_orders_rejected() {
        let share_then_prune = Recipe {
            stages: vec![
                StageSpec::Share(ShareSpec::default()),
                StageSpec::Prune(PruneSpec::default()),
            ],
            exec: ExecConfig::serial(),
            ..Recipe::default()
        };
        assert!(Pipeline::from_recipe(&share_then_prune).is_err());
        let lcc_then_share = Recipe {
            stages: vec![
                StageSpec::Lcc(LccSpec::default()),
                StageSpec::Share(ShareSpec::default()),
            ],
            exec: ExecConfig::serial(),
            ..Recipe::default()
        };
        assert!(Pipeline::from_recipe(&lcc_then_share).is_err());
        let twice = Recipe {
            stages: vec![
                StageSpec::Prune(PruneSpec::default()),
                StageSpec::Prune(PruneSpec::default()),
            ],
            exec: ExecConfig::serial(),
            ..Recipe::default()
        };
        assert!(Pipeline::from_recipe(&twice).is_err());
    }

    #[test]
    fn empty_pipeline_is_identity() {
        let w = demo_weights(8, 2, 2, 2);
        let p = Pipeline::from_recipe(&Recipe {
            stages: vec![],
            exec: ExecConfig::serial(),
            ..Recipe::default()
        })
        .unwrap();
        let model = p.run(&w).unwrap();
        assert!(model.report().stages.is_empty());
        assert_eq!(model.state().dense(), &w);
        assert_eq!(model.report().final_additions(), model.report().baseline_additions);
    }

    #[test]
    fn custom_stage_composes_and_blocks_serialization() {
        struct ScaleStage;
        impl Stage for ScaleStage {
            fn name(&self) -> &'static str {
                "scale"
            }
            fn apply(&self, state: &mut ModelState) -> Result<()> {
                // a no-op restructuring stand-in: states expose enough to
                // verify the hook ran
                assert!(state.active_columns() > 0);
                Ok(())
            }
        }
        let p = Pipeline::builder()
            .prune(1e-6)
            .stage(Box::new(ScaleStage))
            .lcc(&LccConfig::fs())
            .exec(ExecConfig::serial())
            .build()
            .unwrap();
        assert!(p.recipe().is_none(), "custom stages are not serializable");
        let model = p.run(&demo_weights(8, 2, 3, 3)).unwrap();
        let names: Vec<&str> = model.report().stages.iter().map(|s| s.stage.as_str()).collect();
        assert_eq!(names, vec!["prune", "scale", "lcc"]);
    }

    #[test]
    fn sharded_pipeline_executor_bit_identical_to_unsharded() {
        use crate::config::{ShardMode, ShardSpec};
        use crate::exec::Executor;
        let w = demo_weights(20, 4, 3, 6);
        let recipe = Recipe { exec: ExecConfig::serial(), ..Recipe::default() };
        let plain = Pipeline::from_recipe(&recipe).unwrap().run(&w).unwrap();
        let sharded_recipe = Recipe {
            shard: Some(ShardSpec { shards: 3, mode: ShardMode::Serial }),
            ..recipe.clone()
        };
        let sharded = Pipeline::from_recipe(&sharded_recipe).unwrap().run(&w).unwrap();
        assert_eq!(plain.report(), sharded.report(), "sharding is a serve-time property");
        assert!(plain.shard_spec().is_none());
        assert_eq!(sharded.shard_spec().unwrap().shards, 3);
        let mut rng = crate::util::Rng::new(14);
        let xs: Vec<Vec<f32>> = (0..9).map(|_| rng.normal_vec(w.cols(), 1.0)).collect();
        let a = plain.executor().execute_batch(&xs);
        let b = sharded.into_executor().execute_batch(&xs);
        assert_eq!(a, b, "sharded artifact serve must be bit-identical");
    }

    #[test]
    fn deterministic_rerun_reports_equal() {
        let w = demo_weights(24, 4, 4, 5);
        let p = Pipeline::from_recipe(&Recipe::default()).unwrap();
        let a = p.run(&w).unwrap();
        let b = p.run(&w).unwrap();
        assert_eq!(a.report(), b.report());
    }
}
