//! The artifact a compression pipeline transforms.
//!
//! [`ModelState`] carries one weight matrix through the staged scheme:
//! pruning compacts columns (recording the kept-column map), sharing
//! replaces the dense matrix with a centroid layer, quantization snaps
//! the live coefficients to a fixed-point grid, and LCC lowers the final
//! coefficients to a shift-add adder graph behind a batch-major engine.
//! Each mutator enforces its ordering contract, so a custom [`super::Stage`]
//! composed into a pipeline cannot silently corrupt the artifact.

use crate::cluster::affinity::{cluster_columns, AffinityParams};
use crate::config::ExecConfig;
use crate::lcc::LccConfig;
use crate::nn::compressed::Layer1;
use crate::prune::compact_columns;
use crate::quant::{matrix_csd_adders, quantize_matrix, FixedPointFormat};
use crate::share::{SharedLayer, SharedLcc};
use crate::tensor::Matrix;
use anyhow::{bail, Result};

/// The evolving compression artifact. Accessors expose every layer of
/// the representation; the `apply_*` mutators are what the built-in
/// stages (and any custom [`super::Stage`]) drive.
#[derive(Clone, Debug)]
pub struct ModelState {
    /// the matrix the pipeline started from (served input dimension)
    original: Matrix,
    /// exact post-restructuring reference the approximation error is
    /// measured against (the compacted dense matrix; quantization and
    /// LCC distort *away* from this)
    reference: Matrix,
    /// original column index feeding each current compact column
    kept: Vec<usize>,
    /// current dense coefficients over the kept columns
    dense: Matrix,
    shared: Option<SharedLayer>,
    lcc: Option<SharedLcc>,
}

impl ModelState {
    pub fn new(w: &Matrix) -> Self {
        ModelState {
            original: w.clone(),
            reference: w.clone(),
            kept: (0..w.cols()).collect(),
            dense: w.clone(),
            shared: None,
            lcc: None,
        }
    }

    /// Resume from an externally built shared layer (e.g. the Fig. 2
    /// coordinator's retrained weight-tying): `dense` is the compacted
    /// post-retraining matrix the sharing approximates, `kept` its
    /// original-column map.
    pub fn from_shared(dense: Matrix, kept: Vec<usize>, shared: SharedLayer) -> Self {
        assert_eq!(kept.len(), dense.cols(), "kept map must cover the dense columns");
        assert_eq!(shared.num_inputs(), dense.cols(), "sharing must cover the dense columns");
        ModelState {
            original: dense.clone(),
            reference: dense.clone(),
            kept,
            dense,
            shared: Some(shared),
            lcc: None,
        }
    }

    // --- accessors ---------------------------------------------------------

    /// Input dimension a served request must provide (pre-prune).
    pub fn input_dim(&self) -> usize {
        self.original.cols()
    }

    pub fn rows(&self) -> usize {
        self.original.rows()
    }

    pub fn original(&self) -> &Matrix {
        &self.original
    }

    pub fn kept(&self) -> &[usize] {
        &self.kept
    }

    pub fn dense(&self) -> &Matrix {
        &self.dense
    }

    pub fn shared(&self) -> Option<&SharedLayer> {
        self.shared.as_ref()
    }

    pub fn lcc(&self) -> Option<&SharedLcc> {
        self.lcc.as_ref()
    }

    pub fn active_columns(&self) -> usize {
        self.dense.cols()
    }

    /// Clusters after sharing; 0 before.
    pub fn clusters(&self) -> usize {
        self.shared.as_ref().map(SharedLayer::num_clusters).unwrap_or(0)
    }

    /// Short name of the current representation.
    pub fn repr_name(&self) -> &'static str {
        if self.lcc.is_some() {
            "lcc"
        } else if self.shared.is_some() {
            "shared"
        } else {
            "dense"
        }
    }

    // --- stage mutators ----------------------------------------------------

    /// Drop columns with l2 norm ≤ `eps`, compacting the dense matrix
    /// and composing the kept-column map. Must run before share/LCC.
    pub fn apply_prune(&mut self, eps: f32) -> Result<()> {
        if self.shared.is_some() || self.lcc.is_some() {
            bail!("prune must run before share/lcc");
        }
        let compact = compact_columns(&self.dense, eps);
        if compact.kept.is_empty() {
            bail!("pruning at eps {eps} removed every column");
        }
        self.kept = compact.kept.iter().map(|&i| self.kept[i]).collect();
        self.dense = compact.weights;
        self.reference = self.dense.clone();
        Ok(())
    }

    /// Cluster the kept columns with affinity propagation and tie them
    /// to centroids. Must run before LCC, at most once.
    pub fn apply_share(&mut self, params: &AffinityParams) -> Result<()> {
        if self.lcc.is_some() {
            bail!("share must run before lcc");
        }
        if self.shared.is_some() {
            bail!("share already applied");
        }
        let clustering = cluster_columns(&self.dense, params);
        self.shared = Some(SharedLayer::from_clustering(&self.dense, &clustering));
        Ok(())
    }

    /// Snap the live coefficients (centroids if shared, the dense matrix
    /// otherwise) to the fixed-point grid. Must run before LCC.
    pub fn apply_quantize(&mut self, fmt: FixedPointFormat) -> Result<()> {
        if self.lcc.is_some() {
            bail!("quantize must run before lcc");
        }
        if let Some(s) = &mut self.shared {
            let (_, deq) = quantize_matrix(&s.centroids, fmt);
            s.centroids = deq;
        } else {
            let (_, deq) = quantize_matrix(&self.dense, fmt);
            self.dense = deq;
        }
        Ok(())
    }

    /// Decompose the live coefficients with LCC and lower them to a
    /// batch-major engine. Without a prior share stage the decomposition
    /// runs over a degenerate one-column-per-cluster sharing whose
    /// segment sums are the identity (the served executor skips them),
    /// so it sees exactly the dense matrix. Terminal: nothing may follow.
    pub fn apply_lcc(&mut self, cfg: &LccConfig, exec: ExecConfig) -> Result<()> {
        if self.lcc.is_some() {
            bail!("lcc already applied");
        }
        let shared = match &self.shared {
            Some(s) => s.clone(),
            None => SharedLayer {
                centroids: self.dense.clone(),
                labels: (0..self.dense.cols()).collect(),
            },
        };
        self.lcc = Some(shared.with_lcc_exec(cfg, exec));
        Ok(())
    }

    // --- derived quantities ------------------------------------------------

    /// Dense reconstruction of the current representation over the kept
    /// columns (what `y = W_kept x_kept` effectively multiplies by).
    pub fn reconstruction(&self) -> Matrix {
        if let Some(slcc) = &self.lcc {
            let approx = slcc.decomposition.to_dense();
            SharedLayer { centroids: approx, labels: slcc.layer.labels.clone() }.expand()
        } else if let Some(s) = &self.shared {
            s.expand()
        } else {
            self.dense.clone()
        }
    }

    /// Relative Frobenius error of the reconstruction against the exact
    /// post-prune reference.
    pub fn rel_err(&self) -> f64 {
        let recon = self.reconstruction();
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (&a, &b) in recon.data().iter().zip(self.reference.data()) {
            num += ((a - b) as f64).powi(2);
            den += (b as f64).powi(2);
        }
        if den == 0.0 {
            return if num == 0.0 { 0.0 } else { f64::INFINITY };
        }
        (num / den).sqrt()
    }

    /// Additions to evaluate the current representation once (the paper's
    /// cost metric): CSD adders for dense, segment sums + centroid CSD
    /// for shared, segment sums + graph nodes after LCC.
    pub fn additions(&self, fmt: FixedPointFormat) -> usize {
        if let Some(slcc) = &self.lcc {
            slcc.additions()
        } else if let Some(s) = &self.shared {
            s.additions_with_csd(fmt)
        } else {
            matrix_csd_adders(&self.dense, fmt)
        }
    }

    /// The compressed layer-1 evaluation strategy this state denotes
    /// (cloning); pair with [`ModelState::kept`] for a
    /// [`crate::nn::CompressedMlp`].
    pub fn to_layer1(&self) -> Layer1 {
        if let Some(slcc) = &self.lcc {
            Layer1::SharedLcc(slcc.clone())
        } else if let Some(s) = &self.shared {
            Layer1::Shared(s.clone())
        } else {
            Layer1::Dense(self.dense.clone())
        }
    }

    /// Decompose into the servable executor's parts without cloning:
    /// `(input_dim, rows, kept, dense, shared, lcc)`.
    pub(crate) fn into_executor_parts(
        self,
    ) -> (usize, usize, Vec<usize>, Matrix, Option<SharedLayer>, Option<SharedLcc>) {
        let input_dim = self.original.cols();
        let rows = self.original.rows();
        let ModelState { kept, dense, shared, lcc, .. } = self;
        (input_dim, rows, kept, dense, shared, lcc)
    }

    /// Consume the state into `(kept, Layer1)` without cloning the
    /// engine.
    pub fn into_layer1(self) -> (Vec<usize>, Layer1) {
        let ModelState { kept, dense, shared, lcc, .. } = self;
        let layer1 = if let Some(slcc) = lcc {
            Layer1::SharedLcc(slcc)
        } else if let Some(s) = shared {
            Layer1::Shared(s)
        } else {
            Layer1::Dense(dense)
        };
        (kept, layer1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::demo_weights;

    #[test]
    fn prune_composes_the_kept_map() {
        let w = demo_weights(8, 3, 2, 0); // 9 columns, every 3rd zero
        let mut s = ModelState::new(&w);
        assert_eq!(s.kept(), (0..9).collect::<Vec<_>>());
        s.apply_prune(1e-6).unwrap();
        assert_eq!(s.kept(), &[0, 1, 3, 4, 6, 7]);
        assert_eq!(s.active_columns(), 6);
        assert_eq!(s.rel_err(), 0.0, "pruning is exact over the kept columns");
        // a second prune composes (nothing more to drop here)
        s.apply_prune(1e-6).unwrap();
        assert_eq!(s.kept(), &[0, 1, 3, 4, 6, 7]);
    }

    #[test]
    fn ordering_contracts_enforced() {
        let w = demo_weights(8, 3, 3, 1);
        let mut s = ModelState::new(&w);
        s.apply_share(&AffinityParams::default()).unwrap();
        assert!(s.apply_prune(1e-6).is_err(), "prune after share");
        assert!(s.apply_share(&AffinityParams::default()).is_err(), "share twice");
        s.apply_lcc(&LccConfig::fs(), ExecConfig::serial()).unwrap();
        assert!(s.apply_quantize(FixedPointFormat::default_weights()).is_err());
        assert!(s.apply_lcc(&LccConfig::fs(), ExecConfig::serial()).is_err());
    }

    #[test]
    fn quantize_snaps_to_grid() {
        let w = demo_weights(8, 2, 3, 2);
        let mut s = ModelState::new(&w);
        let fmt = FixedPointFormat::default_weights();
        s.apply_quantize(fmt).unwrap();
        let step = fmt.step() as f32;
        for &v in s.dense().data() {
            let m = v / step;
            assert!((m - m.round()).abs() < 1e-3, "{v} not on the grid");
        }
        assert!(s.rel_err() > 0.0 && s.rel_err() < 0.05);
    }

    #[test]
    fn lcc_without_share_uses_identity_sharing() {
        let w = demo_weights(16, 2, 3, 3);
        let mut s = ModelState::new(&w);
        s.apply_lcc(&LccConfig::fs(), ExecConfig::serial()).unwrap();
        let slcc = s.lcc().unwrap();
        assert_eq!(slcc.layer.num_clusters(), w.cols());
        assert!(slcc.layer.labels.iter().enumerate().all(|(i, &l)| i == l));
        assert_eq!(s.clusters(), 0, "no real sharing happened");
        assert_eq!(s.repr_name(), "lcc");
    }

    #[test]
    fn shared_then_lcc_matches_legacy_composition() {
        let w = demo_weights(16, 3, 4, 4);
        let compact = compact_columns(&w, 1e-6);
        let mut s = ModelState::new(&w);
        s.apply_prune(1e-6).unwrap();
        s.apply_share(&AffinityParams::default()).unwrap();
        s.apply_lcc(&LccConfig::fs(), ExecConfig::serial()).unwrap();

        let clustering = cluster_columns(&compact.weights, &AffinityParams::default());
        let legacy = SharedLayer::from_clustering(&compact.weights, &clustering)
            .with_lcc_exec(&LccConfig::fs(), ExecConfig::serial());
        let x: Vec<f32> = (0..compact.kept.len()).map(|i| (i as f32 * 0.37).sin()).collect();
        assert_eq!(s.lcc().unwrap().apply(&x), legacy.apply(&x));
        assert_eq!(s.additions(FixedPointFormat::default_weights()), legacy.additions());
    }
}
