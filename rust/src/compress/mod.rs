//! The unified compression pipeline: one recipe from raw weights to a
//! served engine.
//!
//! The paper's contribution is a *composed* scheme — pruning, weight
//! sharing, then linear computation coding — and this module makes that
//! composition a first-class, declarative object instead of hand-wired
//! glue (the shape Deep Compression's prune→quantize→encode flow and
//! EIE's compressed-model-to-engine handoff standardized):
//!
//! * [`ModelState`] is the artifact a run transforms: the weight matrix
//!   flowing through prune → share → quantize → LCC, with the kept-column
//!   map, the shared layer and the lowered adder graph accumulating on it.
//! * [`Stage`] is the transformation interface; [`PruneStage`],
//!   [`ShareStage`], [`QuantizeStage`] and [`LccStage`] are the paper's
//!   stages, and custom stages compose next to them.
//! * [`Pipeline`] composes stages (builder or [`Recipe`]) and runs them,
//!   emitting a [`CompressionReport`] — per-stage addition accounting,
//!   approximation error and shapes — publishable through
//!   [`crate::metrics::Metrics`].
//! * [`Recipe`] is the serializable description (`[compress]` TOML +
//!   `LCCNN_COMPRESS_*` env) that deterministically reproduces a run:
//!   same recipe + same weights ⇒ the same report and a bit-identical
//!   engine. `serve::ModelRegistry` loads checkpoints through it, and
//!   the `compress` CLI subcommand lowers a checkpoint straight to an
//!   exec-servable artifact directory (`weight.npy` + `recipe.toml` +
//!   `report.tsv`).
//! * [`PipelineExecutor`] is the servable result: a
//!   [`crate::exec::Executor`] that gathers the kept input features,
//!   segment-sums shared clusters and runs the LCC adder graph on the
//!   batch-major engine — so served models are pruned+shared+LCC'd, not
//!   LCC-only. A `[compress.shard]` recipe section (or `exec.shards`)
//!   partitions the served engine across output-range shards
//!   ([`crate::exec::ShardedExecutor`]), bit-identical to unsharded.
//! * The `network` layer scales all of the above from one matrix to a
//!   whole model: [`NetworkCheckpoint`] (multi-layer `layer<k>.weight.npy`
//!   + `network.toml` checkpoint directories), [`NetworkPipeline`]
//!   (per-layer stage runs steered by `[compress.layer.<k>]` recipe
//!   overrides, aggregated into a [`NetworkReport`]) and
//!   [`NetworkExecutor`] (the chained batch-major serving engine with
//!   bias/activation kernels, a propagated analytic error bound and
//!   per-layer [`crate::exec::LayerStat`] telemetry). [`ChainedExecutor`]
//!   composes arbitrary executors — e.g. remote layer-range workers —
//!   into the same seam.
//! * The [`tune`] layer closes the loop from report back to recipe: a
//!   [`TuneSpec`] names sweep axes over the stack above, and
//!   [`tune::sweep_matrix`] / [`tune::sweep_network`] evaluate every
//!   candidate recipe in parallel, flag the (additions, rel-err)
//!   Pareto frontier ([`pareto_frontier`]) and emit reproducible
//!   `recipe.toml` + `sweep.json` artifacts (the `tune` CLI
//!   subcommand).
//!
//! ```
//! use lccnn::compress::{demo_weights, Pipeline, Recipe};
//!
//! let w = demo_weights(16, 3, 4, 0);
//! let model = Pipeline::from_recipe(&Recipe::default()).unwrap().run(&w).unwrap();
//! assert!(model.report().final_additions() > 0);
//! assert_eq!(model.report().stages.len(), 3); // prune, share, lcc
//! ```

mod executor;
mod network;
mod pipeline;
mod recipe;
mod report;
mod stage;
mod state;
pub mod tune;

pub use executor::PipelineExecutor;
pub use network::{
    demo_network, Activation, ChainedExecutor, CompressedLayer, CompressedNetwork,
    NetworkCheckpoint, NetworkExecutor, NetworkLayer, NetworkPipeline, NetworkReport,
};
pub use pipeline::{CompressedModel, Pipeline, PipelineBuilder};
pub use recipe::{
    LayerOverride, LccSpec, PruneSpec, QuantSpec, Recipe, ShareSpec, StageSpec, TuneSpec,
};
pub use report::{pareto_frontier, CompressionReport, StageReport};
pub use stage::{LccStage, PruneStage, QuantizeStage, ShareStage, Stage};
pub use state::ModelState;
pub use tune::{TunePoint, TuneResult};

use crate::tensor::Matrix;
use crate::util::Rng;

/// Synthetic "post-regularization" weights for demos and smokes:
/// `groups` clusters of `per` near-identical columns plus one
/// exactly-zero (pruned) column per group — so pruning, sharing and LCC
/// all genuinely engage.
pub fn demo_weights(rows: usize, groups: usize, per: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let stride = per + 1;
    let mut w = Matrix::zeros(rows, groups * stride);
    for g in 0..groups {
        let base = rng.normal_vec(rows, 0.8);
        for j in 0..per {
            for r in 0..rows {
                *w.at_mut(r, g * stride + j) = base[r] + 0.005 * rng.normal_f32();
            }
        }
        // column g*stride + per stays zero: pruned
    }
    w
}
