//! Serializable compression recipes.
//!
//! A [`Recipe`] is the declarative description of a pipeline run: which
//! stages, with which parameters, lowered with which engine tuning. It
//! round-trips through a `[compress]` TOML document (plus an `[exec]`
//! section) and layers `LCCNN_COMPRESS_*` environment overrides, so a
//! compression run is reproducible from a single small file: same recipe
//! + same weights ⇒ the same [`super::CompressionReport`] and a
//! bit-identical engine.

use crate::cluster::affinity::AffinityParams;
use crate::config::{
    parse_toml, ExecConfig, ExecMode, LccAlgoConfig, PoolMode, ShardMode, ShardSpec, TomlValue,
};
use crate::lcc::{LccAlgorithm, LccConfig};
use crate::quant::FixedPointFormat;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

type Sections = BTreeMap<String, BTreeMap<String, TomlValue>>;

fn get<'a>(t: &'a Sections, section: &str, key: &str) -> Option<&'a TomlValue> {
    t.get(section).and_then(|s| s.get(key))
}

fn env_parse<T: std::str::FromStr>(name: &str) -> Option<T> {
    std::env::var(name).ok().and_then(|v| v.parse().ok())
}

/// Pruning parameters (columns with l2 norm ≤ `eps` are dropped).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PruneSpec {
    pub eps: f32,
}

impl Default for PruneSpec {
    fn default() -> Self {
        PruneSpec { eps: 1e-6 }
    }
}

/// Weight-sharing parameters (affinity propagation over columns).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShareSpec {
    pub damping: f32,
    pub preference_scale: f32,
    pub max_iters: usize,
    pub convergence_iters: usize,
}

impl Default for ShareSpec {
    fn default() -> Self {
        let p = AffinityParams::default();
        ShareSpec {
            damping: p.damping,
            preference_scale: p.preference_scale,
            max_iters: p.max_iters,
            convergence_iters: p.convergence_iters,
        }
    }
}

impl ShareSpec {
    pub fn to_params(&self) -> AffinityParams {
        AffinityParams {
            damping: self.damping,
            preference_scale: self.preference_scale,
            max_iters: self.max_iters,
            convergence_iters: self.convergence_iters,
            preference: None,
        }
    }
}

/// Fixed-point quantization parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuantSpec {
    pub int_bits: u32,
    pub frac_bits: u32,
}

impl Default for QuantSpec {
    fn default() -> Self {
        let f = FixedPointFormat::default_weights();
        QuantSpec { int_bits: f.int_bits, frac_bits: f.frac_bits }
    }
}

impl QuantSpec {
    pub fn to_format(&self) -> FixedPointFormat {
        FixedPointFormat::new(self.int_bits, self.frac_bits)
    }
}

/// LCC decomposition parameters: the union of the FP and FS knobs plus
/// slicing and error targets, convertible losslessly to/from
/// [`LccConfig`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LccSpec {
    pub algo: LccAlgoConfig,
    /// FP: signed-po2 terms per factor row
    pub terms_per_row: usize,
    /// FP: factor chain length cap
    pub max_factors: usize,
    /// FS: per-row term budget
    pub max_terms_per_row: usize,
    /// vertical slice width; 0 = auto (≈ log2 rows)
    pub slice_width: usize,
    pub target_rel_err: f64,
    /// residual floor matched to the fixed-point grid; 0 disables
    pub quant_step: f64,
    pub shift_min: i32,
    pub shift_max: i32,
}

impl Default for LccSpec {
    fn default() -> Self {
        LccSpec::from_config(&LccConfig::fs())
    }
}

impl LccSpec {
    pub fn from_config(cfg: &LccConfig) -> Self {
        let (algo, terms_per_row, max_factors, max_terms_per_row) = match cfg.algo {
            LccAlgorithm::FullyParallel { terms_per_row, max_factors } => {
                (LccAlgoConfig::Fp, terms_per_row, max_factors, 64)
            }
            LccAlgorithm::FullySequential { max_terms_per_row } => {
                (LccAlgoConfig::Fs, 2, 16, max_terms_per_row)
            }
        };
        LccSpec {
            algo,
            terms_per_row,
            max_factors,
            max_terms_per_row,
            slice_width: cfg.slice_width.unwrap_or(0),
            target_rel_err: cfg.target_rel_err,
            quant_step: cfg.quant_step,
            shift_min: cfg.shift_range.0,
            shift_max: cfg.shift_range.1,
        }
    }

    pub fn to_config(&self) -> LccConfig {
        LccConfig {
            algo: match self.algo {
                LccAlgoConfig::Fp => LccAlgorithm::FullyParallel {
                    terms_per_row: self.terms_per_row,
                    max_factors: self.max_factors,
                },
                LccAlgoConfig::Fs => LccAlgorithm::FullySequential {
                    max_terms_per_row: self.max_terms_per_row,
                },
            },
            slice_width: (self.slice_width > 0).then_some(self.slice_width),
            target_rel_err: self.target_rel_err,
            quant_step: self.quant_step,
            shift_range: (self.shift_min, self.shift_max),
        }
    }
}

/// One stage of a recipe, with its parameters.
#[derive(Clone, Debug, PartialEq)]
pub enum StageSpec {
    Prune(PruneSpec),
    Share(ShareSpec),
    Quantize(QuantSpec),
    Lcc(LccSpec),
}

impl StageSpec {
    /// The stage's TOML/env name.
    pub fn kind(&self) -> &'static str {
        match self {
            StageSpec::Prune(_) => "prune",
            StageSpec::Share(_) => "share",
            StageSpec::Quantize(_) => "quantize",
            StageSpec::Lcc(_) => "lcc",
        }
    }

    /// The default-parameter spec for a stage name, if the name is known.
    pub fn default_for(kind: &str) -> Option<Self> {
        match kind {
            "prune" => Some(StageSpec::Prune(PruneSpec::default())),
            "share" => Some(StageSpec::Share(ShareSpec::default())),
            "quantize" => Some(StageSpec::Quantize(QuantSpec::default())),
            "lcc" => Some(StageSpec::Lcc(LccSpec::default())),
            _ => None,
        }
    }
}

/// Layer the keys present in TOML section `sec` over `spec`'s current
/// parameters. Key coverage matches [`write_stage_section`]'s emission,
/// so parse(emit(spec)) is the identity for every stage — for both the
/// global `[compress.<stage>]` sections and the per-layer
/// `[compress.layer.<k>.<stage>]` sections.
fn read_stage_spec(t: &Sections, sec: &str, spec: &mut StageSpec) -> Result<()> {
    let read_int = |key: &str| -> Option<i64> { get(t, sec, key).and_then(TomlValue::as_int) };
    let read_f = |key: &str| -> Option<f64> { get(t, sec, key).and_then(TomlValue::as_float) };
    match spec {
        StageSpec::Prune(p) => {
            if let Some(v) = read_f("eps") {
                p.eps = v as f32;
            }
        }
        StageSpec::Share(s) => {
            if let Some(v) = read_f("damping") {
                s.damping = v as f32;
            }
            if let Some(v) = read_f("preference_scale") {
                s.preference_scale = v as f32;
            }
            if let Some(v) = read_int("max_iters") {
                s.max_iters = v.max(1) as usize;
            }
            if let Some(v) = read_int("convergence_iters") {
                s.convergence_iters = v.max(1) as usize;
            }
        }
        StageSpec::Quantize(q) => {
            if let Some(v) = read_int("int_bits") {
                q.int_bits = v.clamp(0, 32) as u32;
            }
            if let Some(v) = read_int("frac_bits") {
                q.frac_bits = v.clamp(0, 32) as u32;
            }
        }
        StageSpec::Lcc(l) => {
            if let Some(v) = get(t, sec, "algo").and_then(TomlValue::as_str) {
                l.algo = LccAlgoConfig::parse(v)
                    .with_context(|| format!("[{sec}] algo {v:?} (use fp|fs)"))?;
            }
            if let Some(v) = read_int("terms_per_row") {
                l.terms_per_row = v.max(1) as usize;
            }
            if let Some(v) = read_int("max_factors") {
                l.max_factors = v.max(1) as usize;
            }
            if let Some(v) = read_int("max_terms_per_row") {
                l.max_terms_per_row = v.max(1) as usize;
            }
            if let Some(v) = read_int("slice_width") {
                l.slice_width = v.max(0) as usize;
            }
            if let Some(v) = read_f("target_rel_err") {
                l.target_rel_err = v;
            }
            if let Some(v) = read_f("quant_step") {
                l.quant_step = v;
            }
            if let Some(v) = read_int("shift_min") {
                l.shift_min = v as i32;
            }
            if let Some(v) = read_int("shift_max") {
                l.shift_max = v as i32;
            }
        }
    }
    Ok(())
}

/// Emit every parameter of `st` as TOML section `[section]`
/// ([`read_stage_spec`] is the exact inverse).
fn write_stage_section(s: &mut String, section: &str, st: &StageSpec) {
    match st {
        StageSpec::Prune(p) => {
            let _ = writeln!(s, "\n[{section}]\neps = {}", p.eps);
        }
        StageSpec::Share(sh) => {
            let _ = writeln!(
                s,
                "\n[{section}]\ndamping = {}\npreference_scale = {}\n\
                 max_iters = {}\nconvergence_iters = {}",
                sh.damping, sh.preference_scale, sh.max_iters, sh.convergence_iters
            );
        }
        StageSpec::Quantize(q) => {
            let _ = writeln!(
                s,
                "\n[{section}]\nint_bits = {}\nfrac_bits = {}",
                q.int_bits, q.frac_bits
            );
        }
        StageSpec::Lcc(l) => {
            let algo = match l.algo {
                LccAlgoConfig::Fp => "fp",
                LccAlgoConfig::Fs => "fs",
            };
            let _ = writeln!(
                s,
                "\n[{section}]\nalgo = \"{algo}\"\nterms_per_row = {}\n\
                 max_factors = {}\nmax_terms_per_row = {}\nslice_width = {}\n\
                 target_rel_err = {}\nquant_step = {}\nshift_min = {}\nshift_max = {}",
                l.terms_per_row,
                l.max_factors,
                l.max_terms_per_row,
                l.slice_width,
                l.target_rel_err,
                l.quant_step,
                l.shift_min,
                l.shift_max
            );
        }
    }
}

/// The resolved global spec for a built-in stage `kind`: the recipe's
/// stage when the global list carries it, the stage defaults otherwise.
fn global_stage(stages: &[StageSpec], kind: &str) -> StageSpec {
    stages
        .iter()
        .find(|s| s.kind() == kind)
        .cloned()
        .or_else(|| StageSpec::default_for(kind))
        .expect("built-in stage kind")
}

/// Apply one `LCCNN_COMPRESS_LAYER<k>_<knob>` environment override. A
/// stage knob seeds the layer's override spec from the resolved global
/// stage on first touch, so partial per-layer env tuning inherits the
/// global parameters exactly like a partial
/// `[compress.layer.<k>.<stage>]` TOML section does.
fn apply_layer_env(base: &mut Recipe, k: usize, knob: &str, value: &str) {
    fn parsed<T: std::str::FromStr>(v: &str) -> Option<T> {
        v.parse().ok()
    }
    if knob == "STAGES" {
        let mut list = Vec::new();
        for kind in value.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            if StageSpec::default_for(kind).is_some() {
                list.push(kind.to_string());
            } else {
                log::warn!("LCCNN_COMPRESS_LAYER{k}_STAGES: unknown stage {kind:?} skipped");
            }
        }
        base.layers.entry(k).or_default().stages = Some(list);
        return;
    }
    let Some((kind, field)) = (match knob.split_once('_') {
        Some(("PRUNE", f)) => Some(("prune", f)),
        Some(("SHARE", f)) => Some(("share", f)),
        Some(("QUANT", f)) => Some(("quantize", f)),
        Some(("LCC", f)) => Some(("lcc", f)),
        _ => None,
    }) else {
        log::warn!("LCCNN_COMPRESS_LAYER{k}_{knob}: unknown knob ignored");
        return;
    };
    let mut spec = {
        let seed = global_stage(&base.stages, kind);
        base.layers.get(&k).and_then(|o| o.stage(kind)).unwrap_or(seed)
    };
    let ok = match (&mut spec, field) {
        (StageSpec::Prune(p), "EPS") => parsed::<f32>(value).map(|v| p.eps = v).is_some(),
        (StageSpec::Share(s), "DAMPING") => parsed::<f32>(value).map(|v| s.damping = v).is_some(),
        (StageSpec::Share(s), "PREFERENCE_SCALE") => {
            parsed::<f32>(value).map(|v| s.preference_scale = v).is_some()
        }
        (StageSpec::Quantize(q), "INT_BITS") => {
            parsed::<u32>(value).map(|v| q.int_bits = v.min(32)).is_some()
        }
        (StageSpec::Quantize(q), "FRAC_BITS") => {
            parsed::<u32>(value).map(|v| q.frac_bits = v.min(32)).is_some()
        }
        (StageSpec::Lcc(l), "ALGO") => LccAlgoConfig::parse(value).map(|a| l.algo = a).is_some(),
        (StageSpec::Lcc(l), "SLICE_WIDTH") => {
            parsed::<usize>(value).map(|v| l.slice_width = v).is_some()
        }
        (StageSpec::Lcc(l), "TARGET_REL_ERR") => {
            parsed::<f64>(value).map(|v| l.target_rel_err = v).is_some()
        }
        (StageSpec::Lcc(l), "MAX_TERMS") => {
            parsed::<usize>(value).map(|v| l.max_terms_per_row = v.max(1)).is_some()
        }
        (StageSpec::Lcc(l), "TERMS_PER_ROW") => {
            parsed::<usize>(value).map(|v| l.terms_per_row = v.max(1)).is_some()
        }
        _ => {
            log::warn!("LCCNN_COMPRESS_LAYER{k}_{knob}: unknown knob ignored");
            return;
        }
    };
    if !ok {
        log::warn!("LCCNN_COMPRESS_LAYER{k}_{knob}: unparsable value {value:?} ignored");
        return;
    }
    base.layers.entry(k).or_default().set_stage(spec);
}

/// Per-layer overrides a network recipe carries under
/// `[compress.layer.<k>]` sections (1-based layer index, matching the
/// checkpoint's `layer<k>` naming). Every field is optional: an unset
/// field falls back to the global recipe, so one small section can
/// retune a single stage of a single layer.
/// [`Recipe::layer_recipe`] resolves the overrides into that layer's
/// single-matrix pipeline recipe.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LayerOverride {
    /// replaces the global stage *list* for this layer (e.g. skip
    /// `share` on a trained output layer); `stages = [...]` under the
    /// bare `[compress.layer.<k>]` section
    pub stages: Option<Vec<String>>,
    pub prune: Option<PruneSpec>,
    pub share: Option<ShareSpec>,
    pub quantize: Option<QuantSpec>,
    pub lcc: Option<LccSpec>,
}

impl LayerOverride {
    /// The overriding spec for a stage kind, if this layer carries one.
    pub fn stage(&self, kind: &str) -> Option<StageSpec> {
        match kind {
            "prune" => self.prune.map(StageSpec::Prune),
            "share" => self.share.map(StageSpec::Share),
            "quantize" => self.quantize.map(StageSpec::Quantize),
            "lcc" => self.lcc.map(StageSpec::Lcc),
            _ => None,
        }
    }

    /// Store `spec` in the matching override slot.
    pub fn set_stage(&mut self, spec: StageSpec) {
        match spec {
            StageSpec::Prune(p) => self.prune = Some(p),
            StageSpec::Share(s) => self.share = Some(s),
            StageSpec::Quantize(q) => self.quantize = Some(q),
            StageSpec::Lcc(l) => self.lcc = Some(l),
        }
    }
}

/// A complete, serializable compression recipe: ordered stages plus the
/// engine tuning the lowered graph executes with, and optionally how the
/// served engine is sharded (`[compress.shard]`). Multi-layer (network)
/// checkpoints additionally resolve per-layer stage overrides from
/// [`Recipe::layers`] and gate their end-to-end accuracy on
/// [`Recipe::gate_epsilon`].
///
/// Recipes round-trip exactly through their TOML form — the contract
/// that makes artifacts reproducible from one small file:
///
/// ```
/// use lccnn::compress::{Recipe, StageSpec};
///
/// let text = "[compress]\nstages = [\"prune\", \"lcc\"]\n\n[compress.lcc]\nslice_width = 4\n";
/// let recipe = Recipe::from_toml_str(text).unwrap();
/// assert_eq!(recipe.stages.len(), 2);
/// assert!(matches!(&recipe.stages[1], StageSpec::Lcc(l) if l.slice_width == 4));
/// let back = Recipe::from_toml_str(&recipe.to_toml_string()).unwrap();
/// assert_eq!(back, recipe);
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct Recipe {
    pub stages: Vec<StageSpec>,
    pub exec: ExecConfig,
    /// serve-time sharding of the lowered engine: the artifact's LCC
    /// program is partitioned by output ranges across per-shard engines
    /// (`exec::ShardedExecutor`), bit-identical to the unsharded serve
    pub shard: Option<ShardSpec>,
    /// per-layer overrides for multi-layer (network) checkpoints, keyed
    /// by 1-based layer index (`[compress.layer.<k>]` sections); ignored
    /// by single-matrix pipelines
    pub layers: BTreeMap<usize, LayerOverride>,
    /// accuracy-gate tolerance for network compression
    /// (`[compress.network] gate_epsilon`): the compressed network's
    /// accuracy must stay within this of the dense baseline
    pub gate_epsilon: Option<f64>,
}

impl Default for Recipe {
    /// The paper's full stack: prune → share → LCC (FS), default tuning.
    fn default() -> Self {
        Recipe {
            stages: vec![
                StageSpec::Prune(PruneSpec::default()),
                StageSpec::Share(ShareSpec::default()),
                StageSpec::Lcc(LccSpec::default()),
            ],
            exec: ExecConfig::default(),
            shard: None,
            layers: BTreeMap::new(),
            gate_epsilon: None,
        }
    }
}

impl Recipe {
    /// The historical registry behaviour: LCC the raw matrix, nothing
    /// else (the registry's legacy single-matrix load before recipes,
    /// still the fallback for bare `.npy` checkpoints).
    pub fn lcc_only(cfg: &LccConfig, exec: ExecConfig) -> Self {
        Recipe {
            stages: vec![StageSpec::Lcc(LccSpec::from_config(cfg))],
            exec,
            ..Recipe::default()
        }
    }

    /// The single-matrix recipe layer `k` (1-based) of a network
    /// resolves to: the layer's `stages` override when present (the
    /// global stage list otherwise), each stage taking the layer's
    /// parameter override when present and the global stage's parameters
    /// (or stage defaults) otherwise. The returned recipe carries no
    /// layer overrides of its own; engine tuning and the shard spec are
    /// inherited unchanged.
    pub fn layer_recipe(&self, k: usize) -> Result<Recipe> {
        let ov = self.layers.get(&k);
        let kinds: Vec<String> = match ov.and_then(|o| o.stages.as_ref()) {
            Some(list) => list.clone(),
            None => self.stages.iter().map(|s| s.kind().to_string()).collect(),
        };
        let mut stages = Vec::with_capacity(kinds.len());
        for kind in &kinds {
            let spec = ov
                .and_then(|o| o.stage(kind))
                .or_else(|| self.stages.iter().find(|s| s.kind() == kind.as_str()).cloned())
                .or_else(|| StageSpec::default_for(kind));
            match spec {
                Some(s) => stages.push(s),
                None => bail!("layer {k}: unknown stage {kind:?} (use prune|share|quantize|lcc)"),
            }
        }
        Ok(Recipe {
            stages,
            exec: self.exec,
            shard: self.shard,
            layers: BTreeMap::new(),
            gate_epsilon: None,
        })
    }

    /// The effective serve-time sharding: the explicit `[compress.shard]`
    /// section when present, else the engine tuning's `shards` knob
    /// ([`ShardSpec::effective`]). `None` = one unsharded engine.
    pub fn shard_spec(&self) -> Option<ShardSpec> {
        ShardSpec::effective(self.shard, &self.exec)
    }

    /// The recipe to use for a checkpoint path: an artifact directory
    /// carrying a `recipe.toml` (what `lccnn compress --out` writes) is
    /// loaded through it; anything else falls back to the legacy
    /// LCC-only load with env-tuned engine settings.
    pub fn for_checkpoint(path: &Path) -> Result<Self> {
        let recipe_path = path.join("recipe.toml");
        if path.is_dir() && recipe_path.is_file() {
            Self::from_toml(&recipe_path)
        } else {
            Ok(Self::lcc_only(&LccConfig::fs(), ExecConfig::from_env()))
        }
    }

    pub fn from_toml(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read recipe {}", path.display()))?;
        Self::from_toml_str(&text).with_context(|| format!("parse recipe {}", path.display()))
    }

    /// Parse a recipe document. `[compress] stages = [...]` names the
    /// stage order (an explicit empty list is the identity pipeline);
    /// when the key is absent, the `[compress.<stage>]` sections present
    /// are run in canonical order (prune, share, quantize, lcc), and a
    /// document with no compress sections at all gets the default
    /// prune→share→lcc stack. A `[compress.shard]` section (keys
    /// `shards`, `mode = "serial"|"parallel"`) shards the served engine.
    /// Unset keys keep their defaults.
    ///
    /// Network documents add `[compress.layer.<k>]` sections (1-based
    /// layer index; `stages = [...]` replaces that layer's stage list)
    /// with `[compress.layer.<k>.<stage>]` subsections whose keys layer
    /// over the resolved *global* stage parameters, and
    /// `[compress.network] gate_epsilon = <f64>` declares the accuracy
    /// gate. Unknown layer keys, stage names, and non-integer layer
    /// indices are typed errors.
    pub fn from_toml_str(text: &str) -> Result<Self> {
        let t = parse_toml(text)?;
        let exec = ExecConfig::overrides(&t, "exec", ExecConfig::default());
        const CANONICAL: [&str; 4] = ["prune", "share", "quantize", "lcc"];
        let kinds: Vec<String> = match get(&t, "compress", "stages") {
            Some(TomlValue::Array(items)) => items
                .iter()
                .map(|v| {
                    v.as_str()
                        .map(str::to_string)
                        .with_context(|| format!("[compress] stages entry {v:?} must be a string"))
                })
                .collect::<Result<_>>()?,
            Some(v) => bail!("[compress] stages must be an array of strings, got {v:?}"),
            None => {
                let present: Vec<String> = CANONICAL
                    .iter()
                    .filter(|k| t.contains_key(&format!("compress.{k}")))
                    .map(|k| k.to_string())
                    .collect();
                if present.is_empty() {
                    Recipe::default().stages.iter().map(|s| s.kind().to_string()).collect()
                } else {
                    present
                }
            }
        };
        let mut stages = Vec::with_capacity(kinds.len());
        for kind in &kinds {
            let Some(mut spec) = StageSpec::default_for(kind) else {
                bail!("unknown compress stage {kind:?} (use prune|share|quantize|lcc)");
            };
            read_stage_spec(&t, &format!("compress.{kind}"), &mut spec)?;
            stages.push(spec);
        }
        let shard = t.contains_key("compress.shard").then(|| {
            let mut s = ShardSpec::default();
            if let Some(v) = get(&t, "compress.shard", "shards").and_then(TomlValue::as_int) {
                s.shards = v.max(1) as usize;
            }
            if let Some(v) = get(&t, "compress.shard", "mode")
                .and_then(TomlValue::as_str)
                .and_then(ShardMode::parse)
            {
                s.mode = v;
            }
            s
        });
        let mut layers: BTreeMap<usize, LayerOverride> = BTreeMap::new();
        for (section, keys) in &t {
            let Some(rest) = section.strip_prefix("compress.layer.") else {
                continue;
            };
            let (idx, stage_kind) = match rest.split_once('.') {
                Some((i, k)) => (i, Some(k)),
                None => (rest, None),
            };
            let k: usize = match idx.parse().ok().filter(|&k| k >= 1) {
                Some(k) => k,
                None => bail!("[{section}] layer index {idx:?} must be an integer >= 1"),
            };
            let ov = layers.entry(k).or_default();
            match stage_kind {
                // bare [compress.layer.<k>]: only the stage-list key is legal
                None => {
                    for key in keys.keys() {
                        if key != "stages" {
                            bail!(
                                "[{section}] unknown key {key:?} (layer sections take `stages` \
                                 plus [compress.layer.<k>.<stage>] subsections)"
                            );
                        }
                    }
                    if let Some(v) = keys.get("stages") {
                        let TomlValue::Array(items) = v else {
                            bail!("[{section}] stages must be an array of strings, got {v:?}");
                        };
                        let mut list = Vec::with_capacity(items.len());
                        for item in items {
                            let kind = item.as_str().with_context(|| {
                                format!("[{section}] stages entry {item:?} must be a string")
                            })?;
                            if StageSpec::default_for(kind).is_none() {
                                bail!(
                                    "[{section}] unknown stage {kind:?} \
                                     (use prune|share|quantize|lcc)"
                                );
                            }
                            list.push(kind.to_string());
                        }
                        ov.stages = Some(list);
                    }
                }
                // [compress.layer.<k>.<stage>]: seed from the *resolved
                // global* stage so a partial section inherits the global
                // tuning, then layer the section's keys over it
                Some(kind) => {
                    let mut spec = stages
                        .iter()
                        .find(|s| s.kind() == kind)
                        .cloned()
                        .or_else(|| StageSpec::default_for(kind))
                        .with_context(|| {
                            format!(
                                "[{section}] unknown stage {kind:?} (use prune|share|quantize|lcc)"
                            )
                        })?;
                    read_stage_spec(&t, section, &mut spec)?;
                    ov.set_stage(spec);
                }
            }
        }
        let gate_epsilon =
            get(&t, "compress.network", "gate_epsilon").and_then(TomlValue::as_float);
        Ok(Recipe { stages, exec, shard, layers, gate_epsilon })
    }

    /// Render the recipe as a TOML document that [`Recipe::from_toml_str`]
    /// parses back to an equal value.
    pub fn to_toml_string(&self) -> String {
        let mut s = String::from("# lccnn compression recipe (README §Compression pipeline)\n");
        let kinds: Vec<String> = self.stages.iter().map(|st| format!("{:?}", st.kind())).collect();
        let _ = writeln!(s, "[compress]\nstages = [{}]", kinds.join(", "));
        for st in &self.stages {
            write_stage_section(&mut s, &format!("compress.{}", st.kind()), st);
        }
        for (k, ov) in &self.layers {
            let _ = writeln!(s, "\n[compress.layer.{k}]");
            if let Some(list) = &ov.stages {
                let kinds: Vec<String> = list.iter().map(|st| format!("{st:?}")).collect();
                let _ = writeln!(s, "stages = [{}]", kinds.join(", "));
            }
            for kind in ["prune", "share", "quantize", "lcc"] {
                if let Some(spec) = ov.stage(kind) {
                    write_stage_section(&mut s, &format!("compress.layer.{k}.{kind}"), &spec);
                }
            }
        }
        if let Some(eps) = self.gate_epsilon {
            let _ = writeln!(s, "\n[compress.network]\ngate_epsilon = {eps}");
        }
        if let Some(sh) = &self.shard {
            let _ = writeln!(
                s,
                "\n[compress.shard]\nshards = {}\nmode = \"{}\"",
                sh.shards,
                sh.mode.as_str()
            );
        }
        let e = &self.exec;
        let pool_mode = match e.pool_mode {
            PoolMode::Scoped => "scoped",
            PoolMode::Persistent => "persistent",
        };
        let _ = writeln!(
            s,
            "\n[exec]\nthreads = {}\nchunk = {}\nparallel_min_batch = {}\n\
             level_parallel_min_ops = {}\npool_mode = \"{pool_mode}\"\n\
             pool_spin_us = {}\npool_park_ms = {}\nshards = {}\nshard_mode = \"{}\"\n\
             exec_mode = \"{}\"\nfixed_frac_bits = {}\nfixed_acc_bits = {}\n\
             fixed_saturation = \"{}\"",
            e.threads,
            e.chunk,
            e.parallel_min_batch,
            e.level_parallel_min_ops,
            e.pool_spin_us,
            e.pool_park_ms,
            e.shards,
            e.shard_mode.as_str(),
            e.exec_mode.as_str(),
            e.fixed_frac_bits,
            e.fixed_acc.bits(),
            e.fixed_sat.as_str()
        );
        s
    }

    /// Write the recipe next to an artifact (`recipe.toml`), creating
    /// parent directories.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("mkdir {}", parent.display()))?;
        }
        std::fs::write(path, self.to_toml_string())
            .with_context(|| format!("write recipe {}", path.display()))
    }

    /// Environment overrides over the default recipe.
    pub fn from_env() -> Self {
        Self::from_env_over(Recipe::default())
    }

    /// Layer `LCCNN_COMPRESS_*` environment overrides over `base`:
    /// `LCCNN_COMPRESS_STAGES` (comma-separated stage names) reshapes the
    /// stage list (keeping `base`'s parameters for stages it retains);
    /// per-stage knobs — `LCCNN_COMPRESS_PRUNE_EPS`,
    /// `LCCNN_COMPRESS_SHARE_DAMPING`,
    /// `LCCNN_COMPRESS_SHARE_PREFERENCE_SCALE`,
    /// `LCCNN_COMPRESS_QUANT_INT_BITS`, `LCCNN_COMPRESS_QUANT_FRAC_BITS`,
    /// `LCCNN_COMPRESS_LCC_ALGO` (`fp`|`fs`),
    /// `LCCNN_COMPRESS_LCC_SLICE_WIDTH`,
    /// `LCCNN_COMPRESS_LCC_TARGET_REL_ERR`,
    /// `LCCNN_COMPRESS_LCC_MAX_TERMS`, `LCCNN_COMPRESS_LCC_TERMS_PER_ROW`
    /// — apply to the matching stage when present; engine tuning layers
    /// the `LCCNN_EXEC_*` variables over `base.exec`.
    ///
    /// Network knobs: `LCCNN_COMPRESS_LAYER<k>_<KNOB>` (e.g.
    /// `LCCNN_COMPRESS_LAYER2_LCC_TARGET_REL_ERR`,
    /// `LCCNN_COMPRESS_LAYER3_STAGES`) layers per-layer overrides over
    /// `base.layers` after the global knobs apply, and
    /// `LCCNN_COMPRESS_GATE_EPSILON` sets the network accuracy-gate
    /// tolerance.
    pub fn from_env_over(mut base: Recipe) -> Recipe {
        if let Ok(raw) = std::env::var("LCCNN_COMPRESS_STAGES") {
            let mut stages = Vec::new();
            for kind in raw.split(',').map(str::trim).filter(|s| !s.is_empty()) {
                let spec = base
                    .stages
                    .iter()
                    .find(|s| s.kind() == kind)
                    .cloned()
                    .or_else(|| StageSpec::default_for(kind));
                match spec {
                    Some(s) => stages.push(s),
                    None => log::warn!("LCCNN_COMPRESS_STAGES: unknown stage {kind:?} skipped"),
                }
            }
            base.stages = stages;
        }
        for spec in &mut base.stages {
            match spec {
                StageSpec::Prune(p) => {
                    if let Some(v) = env_parse::<f32>("LCCNN_COMPRESS_PRUNE_EPS") {
                        p.eps = v;
                    }
                }
                StageSpec::Share(s) => {
                    if let Some(v) = env_parse::<f32>("LCCNN_COMPRESS_SHARE_DAMPING") {
                        s.damping = v;
                    }
                    if let Some(v) = env_parse::<f32>("LCCNN_COMPRESS_SHARE_PREFERENCE_SCALE") {
                        s.preference_scale = v;
                    }
                }
                StageSpec::Quantize(q) => {
                    if let Some(v) = env_parse::<u32>("LCCNN_COMPRESS_QUANT_INT_BITS") {
                        q.int_bits = v.min(32);
                    }
                    if let Some(v) = env_parse::<u32>("LCCNN_COMPRESS_QUANT_FRAC_BITS") {
                        q.frac_bits = v.min(32);
                    }
                }
                StageSpec::Lcc(l) => {
                    if let Some(a) = std::env::var("LCCNN_COMPRESS_LCC_ALGO")
                        .ok()
                        .as_deref()
                        .and_then(LccAlgoConfig::parse)
                    {
                        l.algo = a;
                    }
                    if let Some(v) = env_parse::<usize>("LCCNN_COMPRESS_LCC_SLICE_WIDTH") {
                        l.slice_width = v;
                    }
                    if let Some(v) = env_parse::<f64>("LCCNN_COMPRESS_LCC_TARGET_REL_ERR") {
                        l.target_rel_err = v;
                    }
                    if let Some(v) = env_parse::<usize>("LCCNN_COMPRESS_LCC_MAX_TERMS") {
                        l.max_terms_per_row = v.max(1);
                    }
                    if let Some(v) = env_parse::<usize>("LCCNN_COMPRESS_LCC_TERMS_PER_ROW") {
                        l.terms_per_row = v.max(1);
                    }
                }
            }
        }
        // per-layer knobs apply after the global set, so a layer override
        // always wins; sorted for a deterministic application order
        let mut layer_vars: Vec<(usize, String, String)> = std::env::vars()
            .filter_map(|(name, value)| {
                let rest = name.strip_prefix("LCCNN_COMPRESS_LAYER")?;
                let (idx, knob) = rest.split_once('_')?;
                let idx = idx.parse().ok().filter(|&i| i >= 1)?;
                Some((idx, knob.to_string(), value))
            })
            .collect();
        layer_vars.sort();
        for (k, knob, value) in &layer_vars {
            apply_layer_env(&mut base, *k, knob, value);
        }
        if let Some(v) = env_parse::<f64>("LCCNN_COMPRESS_GATE_EPSILON") {
            base.gate_epsilon = Some(v);
        }
        base.exec = ExecConfig::from_env_over(base.exec);
        base
    }
}

/// The axes of a [`super::tune`] sweep: every combination of the listed
/// values is one candidate [`Recipe`] (the paper's prune → share → LCC
/// stack with those parameters). Like [`Recipe`], the spec is fully
/// serializable — a `[tune]` TOML section plus `LCCNN_TUNE_*`
/// environment overrides — so a sweep is reproducible from one small
/// file: same spec + same seed + same weights ⇒ the same Pareto
/// frontier and byte-identical emitted `recipe.toml` files.
///
/// ```
/// use lccnn::compress::TuneSpec;
///
/// let spec = TuneSpec::from_toml_str("[tune]\nprune_eps = [0.001]\nbudget = 4\n").unwrap();
/// assert_eq!(spec.prune_eps, vec![0.001]);
/// assert_eq!(spec.budget, 4);
/// let back = TuneSpec::from_toml_str(&spec.to_toml_string()).unwrap();
/// assert_eq!(back, spec);
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct TuneSpec {
    /// prune thresholds to sweep (`PruneSpec::eps` values)
    pub prune_eps: Vec<f64>,
    /// weight-sharing preference scales (`ShareSpec::preference_scale`,
    /// the knob steering the affinity-propagation cluster count); a
    /// value of 0 drops the share stage from that candidate entirely
    pub share_scale: Vec<f64>,
    /// LCC algorithms to sweep (`fp` | `fs`)
    pub lcc_algos: Vec<LccAlgoConfig>,
    /// LCC vertical slice widths (`LccSpec::slice_width`; 0 = auto)
    pub lcc_widths: Vec<usize>,
    /// engine datapaths (`float` | `fixed`); the compression report is
    /// datapath-independent, so extra modes only add distinct points
    /// when `measure` is on
    pub exec_modes: Vec<ExecMode>,
    /// serve-time shard counts (`[compress.shard]`); values <= 1 mean
    /// one unsharded engine — like `exec_modes`, a measurement axis
    pub shards: Vec<usize>,
    /// evaluate at most this many candidates (a seeded uniform
    /// subsample of the full grid); 0 = the whole grid
    pub budget: usize,
    /// seed for the budget subsample and the demo input weights
    pub seed: u64,
    /// also time each candidate's served engine (µs/sample); off by
    /// default because wall-clock numbers are host-dependent and would
    /// break the byte-determinism of `sweep.json`
    pub measure: bool,
}

impl Default for TuneSpec {
    /// A small real grid around the paper's operating points: 2 prune
    /// thresholds × share off/on × FS/FP × 2 slice widths = 16
    /// compression-distinct candidates, float-only and unsharded.
    fn default() -> Self {
        TuneSpec {
            prune_eps: vec![1e-6, 1e-3],
            share_scale: vec![0.0, 0.3],
            lcc_algos: vec![LccAlgoConfig::Fs, LccAlgoConfig::Fp],
            lcc_widths: vec![0, 4],
            exec_modes: vec![ExecMode::Float],
            shards: vec![1],
            budget: 0,
            seed: 0,
            measure: false,
        }
    }
}

impl TuneSpec {
    /// Number of candidates in the full grid (before any `budget` cap).
    pub fn grid_size(&self) -> usize {
        self.prune_eps.len()
            * self.share_scale.len()
            * self.lcc_algos.len()
            * self.lcc_widths.len()
            * self.exec_modes.len()
            * self.shards.len()
    }

    /// Every axis must carry at least one value for the grid to be
    /// non-empty; typed error otherwise.
    pub fn validate(&self) -> Result<()> {
        for (name, len) in [
            ("prune_eps", self.prune_eps.len()),
            ("share_scale", self.share_scale.len()),
            ("lcc_algos", self.lcc_algos.len()),
            ("lcc_widths", self.lcc_widths.len()),
            ("exec_modes", self.exec_modes.len()),
            ("shards", self.shards.len()),
        ] {
            if len == 0 {
                bail!("[tune] {name} is empty: every sweep axis needs at least one value");
            }
        }
        Ok(())
    }

    pub fn from_toml(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read tune spec {}", path.display()))?;
        Self::from_toml_str(&text).with_context(|| format!("parse tune spec {}", path.display()))
    }

    /// Parse a `[tune]` document, layering the keys present over the
    /// default grid. Unknown algorithm/mode names and wrong-typed keys
    /// are typed errors; absent keys keep their defaults.
    pub fn from_toml_str(text: &str) -> Result<Self> {
        let t = parse_toml(text)?;
        let mut s = TuneSpec::default();
        let sec = "tune";
        if let Some(v) = get(&t, sec, "prune_eps") {
            s.prune_eps =
                v.as_float_array().with_context(|| format!("[tune] prune_eps {v:?}"))?;
        }
        if let Some(v) = get(&t, sec, "share_scale") {
            s.share_scale =
                v.as_float_array().with_context(|| format!("[tune] share_scale {v:?}"))?;
        }
        if let Some(v) = get(&t, sec, "lcc_algos") {
            let names = v.as_str_array().with_context(|| format!("[tune] lcc_algos {v:?}"))?;
            s.lcc_algos = names
                .iter()
                .map(|n| {
                    LccAlgoConfig::parse(n)
                        .with_context(|| format!("[tune] lcc_algos entry {n:?} (use fp|fs)"))
                })
                .collect::<Result<_>>()?;
        }
        if let Some(v) = get(&t, sec, "lcc_widths") {
            s.lcc_widths =
                v.as_usize_array().with_context(|| format!("[tune] lcc_widths {v:?}"))?;
        }
        if let Some(v) = get(&t, sec, "exec_modes") {
            let names = v.as_str_array().with_context(|| format!("[tune] exec_modes {v:?}"))?;
            s.exec_modes = names
                .iter()
                .map(|n| {
                    ExecMode::parse(n)
                        .with_context(|| format!("[tune] exec_modes entry {n:?} (use float|fixed)"))
                })
                .collect::<Result<_>>()?;
        }
        if let Some(v) = get(&t, sec, "shards") {
            s.shards = v.as_usize_array().with_context(|| format!("[tune] shards {v:?}"))?;
        }
        if let Some(v) = get(&t, sec, "budget").and_then(TomlValue::as_int) {
            s.budget = v.max(0) as usize;
        }
        if let Some(v) = get(&t, sec, "seed").and_then(TomlValue::as_int) {
            s.seed = v.max(0) as u64;
        }
        if let Some(v) = get(&t, sec, "measure").and_then(TomlValue::as_bool) {
            s.measure = v;
        }
        Ok(s)
    }

    /// Render the spec as a TOML document that [`TuneSpec::from_toml_str`]
    /// parses back to an equal value.
    pub fn to_toml_string(&self) -> String {
        fn floats(xs: &[f64]) -> String {
            xs.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(", ")
        }
        fn ints(xs: &[usize]) -> String {
            xs.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(", ")
        }
        let algos: Vec<String> = self
            .lcc_algos
            .iter()
            .map(|a| match a {
                LccAlgoConfig::Fp => "\"fp\"".to_string(),
                LccAlgoConfig::Fs => "\"fs\"".to_string(),
            })
            .collect();
        let modes: Vec<String> =
            self.exec_modes.iter().map(|m| format!("{:?}", m.as_str())).collect();
        let mut s = String::from("# lccnn tune spec (README §Recipe tuning)\n");
        let _ = writeln!(
            s,
            "[tune]\nprune_eps = [{}]\nshare_scale = [{}]\nlcc_algos = [{}]\n\
             lcc_widths = [{}]\nexec_modes = [{}]\nshards = [{}]\nbudget = {}\nseed = {}\n\
             measure = {}",
            floats(&self.prune_eps),
            floats(&self.share_scale),
            algos.join(", "),
            ints(&self.lcc_widths),
            modes.join(", "),
            ints(&self.shards),
            self.budget,
            self.seed,
            self.measure
        );
        s
    }

    /// Write the spec next to a sweep's output (`tune.toml`), creating
    /// parent directories.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("mkdir {}", parent.display()))?;
        }
        std::fs::write(path, self.to_toml_string())
            .with_context(|| format!("write tune spec {}", path.display()))
    }

    /// Environment overrides over the default grid.
    pub fn from_env() -> Self {
        Self::from_env_over(TuneSpec::default())
    }

    /// Layer `LCCNN_TUNE_*` environment overrides over `base`: the list
    /// axes take comma-separated values (`LCCNN_TUNE_PRUNE_EPS`,
    /// `LCCNN_TUNE_SHARE_SCALE`, `LCCNN_TUNE_LCC_ALGOS`,
    /// `LCCNN_TUNE_LCC_WIDTHS`, `LCCNN_TUNE_EXEC_MODES`,
    /// `LCCNN_TUNE_SHARDS`), the scalars plain values
    /// (`LCCNN_TUNE_BUDGET`, `LCCNN_TUNE_SEED`, `LCCNN_TUNE_MEASURE`).
    /// Unparsable entries are warned about and skipped, matching the
    /// other `LCCNN_*` env layers.
    pub fn from_env_over(mut base: TuneSpec) -> TuneSpec {
        fn env_list<T>(name: &str, parse: impl Fn(&str) -> Option<T>) -> Option<Vec<T>> {
            let raw = std::env::var(name).ok()?;
            let mut out = Vec::new();
            for item in raw.split(',').map(str::trim).filter(|s| !s.is_empty()) {
                match parse(item) {
                    Some(v) => out.push(v),
                    None => log::warn!("{name}: unparsable entry {item:?} skipped"),
                }
            }
            (!out.is_empty()).then_some(out)
        }
        if let Some(v) = env_list("LCCNN_TUNE_PRUNE_EPS", |s| s.parse::<f64>().ok()) {
            base.prune_eps = v;
        }
        if let Some(v) = env_list("LCCNN_TUNE_SHARE_SCALE", |s| s.parse::<f64>().ok()) {
            base.share_scale = v;
        }
        if let Some(v) = env_list("LCCNN_TUNE_LCC_ALGOS", LccAlgoConfig::parse) {
            base.lcc_algos = v;
        }
        if let Some(v) = env_list("LCCNN_TUNE_LCC_WIDTHS", |s| s.parse::<usize>().ok()) {
            base.lcc_widths = v;
        }
        if let Some(v) = env_list("LCCNN_TUNE_EXEC_MODES", ExecMode::parse) {
            base.exec_modes = v;
        }
        if let Some(v) = env_list("LCCNN_TUNE_SHARDS", |s| s.parse::<usize>().ok()) {
            base.shards = v;
        }
        if let Some(v) = env_parse::<usize>("LCCNN_TUNE_BUDGET") {
            base.budget = v;
        }
        if let Some(v) = env_parse::<u64>("LCCNN_TUNE_SEED") {
            base.seed = v;
        }
        if let Ok(v) = std::env::var("LCCNN_TUNE_MEASURE") {
            base.measure = !v.is_empty() && v != "0" && v != "false";
        }
        base
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_recipe_is_the_paper_stack() {
        let r = Recipe::default();
        let kinds: Vec<_> = r.stages.iter().map(StageSpec::kind).collect();
        assert_eq!(kinds, vec!["prune", "share", "lcc"]);
    }

    #[test]
    fn toml_round_trip_default() {
        let r = Recipe::default();
        let text = r.to_toml_string();
        let back = Recipe::from_toml_str(&text).unwrap();
        assert_eq!(back, r, "\n{text}");
    }

    #[test]
    fn toml_round_trip_custom() {
        let mut lcc = LccSpec::from_config(&LccConfig::fp());
        lcc.slice_width = 4;
        lcc.target_rel_err = 0.015;
        let r = Recipe {
            stages: vec![
                StageSpec::Prune(PruneSpec { eps: 3e-5 }),
                StageSpec::Quantize(QuantSpec { int_bits: 3, frac_bits: 6 }),
                StageSpec::Share(ShareSpec { damping: 0.8, ..Default::default() }),
                StageSpec::Lcc(lcc),
            ],
            exec: ExecConfig { threads: 2, chunk: 16, ..ExecConfig::default() },
            shard: Some(ShardSpec { shards: 3, mode: ShardMode::Serial }),
            ..Recipe::default()
        };
        let back = Recipe::from_toml_str(&r.to_toml_string()).unwrap();
        assert_eq!(back, r, "\n{}", r.to_toml_string());
    }

    #[test]
    fn toml_round_trip_fixed_exec_mode() {
        use crate::config::{AccWidth, ExecMode, Saturation};
        let r = Recipe {
            exec: ExecConfig {
                exec_mode: ExecMode::Fixed,
                fixed_frac_bits: 14,
                fixed_acc: AccWidth::W32,
                fixed_sat: Saturation::Wrap,
                ..ExecConfig::default()
            },
            ..Recipe::default()
        };
        let text = r.to_toml_string();
        let back = Recipe::from_toml_str(&text).unwrap();
        assert_eq!(back, r, "\n{text}");
        assert_eq!(back.exec.exec_mode, ExecMode::Fixed);
        assert_eq!(back.exec.fixed_acc, AccWidth::W32);
    }

    #[test]
    fn explicit_empty_stages_is_identity_pipeline() {
        let r = Recipe::from_toml_str("[compress]\nstages = []\n").unwrap();
        assert!(r.stages.is_empty());
    }

    #[test]
    fn missing_stages_key_infers_from_sections() {
        let r = Recipe::from_toml_str("[compress.lcc]\nalgo = \"fp\"\n").unwrap();
        assert_eq!(r.stages.len(), 1);
        assert!(matches!(r.stages[0], StageSpec::Lcc(l) if l.algo == LccAlgoConfig::Fp));
        // nothing at all -> the default stack
        let d = Recipe::from_toml_str("").unwrap();
        assert_eq!(d.stages, Recipe::default().stages);
    }

    #[test]
    fn unknown_stage_rejected() {
        assert!(Recipe::from_toml_str("[compress]\nstages = [\"nope\"]\n").is_err());
    }

    #[test]
    fn shard_section_parses_and_round_trips() {
        // bare section: the default 2-way parallel split
        let r = Recipe::from_toml_str("[compress.shard]\n").unwrap();
        assert_eq!(r.shard, Some(ShardSpec::default()));
        assert_eq!(r.stages, Recipe::default().stages, "shard section is not a stage");
        // explicit keys
        let r = Recipe::from_toml_str("[compress.shard]\nshards = 4\nmode = \"serial\"\n")
            .unwrap();
        assert_eq!(r.shard, Some(ShardSpec { shards: 4, mode: ShardMode::Serial }));
        assert_eq!(Recipe::from_toml_str(&r.to_toml_string()).unwrap(), r);
        // no section: no sharding
        assert!(Recipe::from_toml_str("").unwrap().shard.is_none());
    }

    #[test]
    fn shard_spec_falls_back_to_exec_shards() {
        let mut r = Recipe::default();
        assert!(r.shard_spec().is_none(), "default recipe is unsharded");
        r.exec.shards = 3;
        r.exec.shard_mode = ShardMode::Serial;
        assert_eq!(
            r.shard_spec(),
            Some(ShardSpec { shards: 3, mode: ShardMode::Serial }),
            "env/TOML exec sharding applies to recipe-served artifacts"
        );
        r.shard = Some(ShardSpec { shards: 5, mode: ShardMode::Parallel });
        assert_eq!(r.shard_spec().unwrap().shards, 5, "explicit section wins");
        // exec shards round-trip through the [exec] section too
        let text = r.to_toml_string();
        assert_eq!(Recipe::from_toml_str(&text).unwrap(), r, "\n{text}");
    }

    #[test]
    fn lcc_spec_config_round_trip() {
        for cfg in [LccConfig::fs(), LccConfig::fp()] {
            let spec = LccSpec::from_config(&cfg);
            assert_eq!(spec.to_config(), cfg);
        }
        let mut cfg = LccConfig::fs();
        cfg.slice_width = Some(6);
        cfg.target_rel_err = 0.005;
        assert_eq!(LccSpec::from_config(&cfg).to_config(), cfg);
    }

    #[test]
    fn lcc_only_matches_legacy_defaults() {
        let r = Recipe::lcc_only(&LccConfig::fs(), ExecConfig::serial());
        assert_eq!(r.stages.len(), 1);
        assert_eq!(r.exec.threads, 1);
        match &r.stages[0] {
            StageSpec::Lcc(l) => assert_eq!(l.to_config(), LccConfig::fs()),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn layer_overrides_round_trip_and_win() {
        let text = "[compress]\nstages = [\"prune\", \"lcc\"]\n\n\
                    [compress.prune]\neps = 0.001\n\n\
                    [compress.lcc]\ntarget_rel_err = 0.01\n\n\
                    [compress.layer.2]\nstages = [\"lcc\"]\n\n\
                    [compress.layer.2.lcc]\ntarget_rel_err = 0.05\n\n\
                    [compress.network]\ngate_epsilon = 0.04\n";
        let r = Recipe::from_toml_str(text).unwrap();
        assert_eq!(r.gate_epsilon, Some(0.04));
        // a layer without overrides resolves to the global recipe
        let l1 = r.layer_recipe(1).unwrap();
        assert_eq!(l1.stages.len(), 2);
        assert!(matches!(l1.stages[0], StageSpec::Prune(p) if (p.eps - 1e-3).abs() < 1e-9));
        assert!(matches!(l1.stages[1], StageSpec::Lcc(l) if l.target_rel_err == 0.01));
        // layer 2: the override wins, the stage list is replaced, and the
        // unset lcc knobs inherit the resolved *global* lcc tuning
        let l2 = r.layer_recipe(2).unwrap();
        assert_eq!(l2.stages.len(), 1);
        match &l2.stages[0] {
            StageSpec::Lcc(l) => {
                assert_eq!(l.target_rel_err, 0.05, "layer override wins over the global");
                assert_eq!(l.max_terms_per_row, LccSpec::default().max_terms_per_row);
            }
            other => panic!("{other:?}"),
        }
        assert!(l2.layers.is_empty() && l2.gate_epsilon.is_none(), "resolved recipe is flat");
        let back = Recipe::from_toml_str(&r.to_toml_string()).unwrap();
        assert_eq!(back, r, "\n{}", r.to_toml_string());
    }

    #[test]
    fn unknown_layer_keys_are_typed_errors() {
        assert!(Recipe::from_toml_str("[compress.layer.0]\n").is_err(), "index must be >= 1");
        assert!(Recipe::from_toml_str("[compress.layer.x]\n").is_err(), "index must be integer");
        assert!(Recipe::from_toml_str("[compress.layer.1]\nnope = 3\n").is_err());
        assert!(Recipe::from_toml_str("[compress.layer.1.nope]\neps = 1.0\n").is_err());
        assert!(
            Recipe::from_toml_str("[compress.layer.1]\nstages = [\"nope\"]\n").is_err(),
            "unknown stage name in a layer stage list"
        );
        // bare layer sections round-trip as empty overrides
        let r = Recipe::from_toml_str("[compress.layer.3]\n").unwrap();
        assert_eq!(r.layers.get(&3), Some(&LayerOverride::default()));
        assert_eq!(Recipe::from_toml_str(&r.to_toml_string()).unwrap(), r);
    }

    // The sole test in this binary touching `LCCNN_COMPRESS_LAYER*` /
    // `LCCNN_COMPRESS_GATE_EPSILON`, so parallel tests never race on
    // them (the global compress knobs live in tests/compress_pipeline.rs
    // under the same one-owner convention).
    #[test]
    fn layer_env_overrides_win_and_round_trip() {
        std::env::set_var("LCCNN_COMPRESS_LAYER7_STAGES", "lcc");
        std::env::set_var("LCCNN_COMPRESS_LAYER7_LCC_TARGET_REL_ERR", "0.02");
        std::env::set_var("LCCNN_COMPRESS_GATE_EPSILON", "0.04");
        let r = Recipe::from_env_over(Recipe::default());
        std::env::remove_var("LCCNN_COMPRESS_LAYER7_STAGES");
        std::env::remove_var("LCCNN_COMPRESS_LAYER7_LCC_TARGET_REL_ERR");
        std::env::remove_var("LCCNN_COMPRESS_GATE_EPSILON");
        assert_eq!(r.gate_epsilon, Some(0.04));
        let l7 = r.layer_recipe(7).unwrap();
        assert_eq!(l7.stages.len(), 1, "layer stage-list env override wins");
        assert!(matches!(&l7.stages[0], StageSpec::Lcc(l) if l.target_rel_err == 0.02));
        // untouched layers keep the global stack
        assert_eq!(r.layer_recipe(1).unwrap().stages, Recipe::default().stages);
        // and the layered recipe still round-trips through TOML
        let text = r.to_toml_string();
        assert_eq!(Recipe::from_toml_str(&text).unwrap(), r, "\n{text}");
    }

    #[test]
    fn tune_spec_defaults_round_trip() {
        let spec = TuneSpec::default();
        assert_eq!(spec.grid_size(), 16, "2 eps x 2 scale x 2 algo x 2 width");
        spec.validate().unwrap();
        let text = spec.to_toml_string();
        assert_eq!(TuneSpec::from_toml_str(&text).unwrap(), spec, "\n{text}");
    }

    #[test]
    fn tune_spec_custom_round_trip_and_layering() {
        let spec = TuneSpec {
            prune_eps: vec![0.01],
            share_scale: vec![0.0],
            lcc_algos: vec![LccAlgoConfig::Fp],
            lcc_widths: vec![8, 16],
            exec_modes: vec![ExecMode::Float, ExecMode::Fixed],
            shards: vec![1, 4],
            budget: 5,
            seed: 42,
            measure: true,
        };
        let text = spec.to_toml_string();
        assert_eq!(TuneSpec::from_toml_str(&text).unwrap(), spec, "\n{text}");
        // absent keys keep their defaults
        let sparse = TuneSpec::from_toml_str("[tune]\nbudget = 3\n").unwrap();
        assert_eq!(sparse.budget, 3);
        assert_eq!(sparse.prune_eps, TuneSpec::default().prune_eps);
        // unknown algo / mode names are typed errors
        assert!(TuneSpec::from_toml_str("[tune]\nlcc_algos = [\"nope\"]\n").is_err());
        assert!(TuneSpec::from_toml_str("[tune]\nexec_modes = [\"nope\"]\n").is_err());
        // an emptied axis is caught by validate()
        let empty = TuneSpec::from_toml_str("[tune]\nshards = []\n").unwrap();
        assert!(empty.validate().is_err());
    }

    #[test]
    fn for_checkpoint_falls_back_to_lcc_only() {
        let dir = std::env::temp_dir().join(format!("lccnn-recipe-none-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let r = Recipe::for_checkpoint(&dir).unwrap();
        assert_eq!(r.stages.len(), 1, "bare dir gets the LCC-only legacy load");
        // an artifact dir with a recipe.toml is loaded through it
        let full = Recipe::default();
        full.save(&dir.join("recipe.toml")).unwrap();
        let r2 = Recipe::for_checkpoint(&dir).unwrap();
        assert_eq!(r2.stages, full.stages);
        std::fs::remove_dir_all(&dir).ok();
    }
}
