//! Structured pruning via group lasso (paper Sec. III-B).
//!
//! The training-side proximal step runs inside the AOT JAX artifact (L1
//! Pallas kernel `prox.py`); this module is the rust-side mirror used to
//! (a) verify artifact parity, (b) extract prune masks from trained
//! weights and (c) physically compact matrices for LCC, which needs
//! *dense small* matrices rather than masked big ones.

use crate::tensor::Matrix;

/// Block soft-thresholding on matrix rows (eq. 8) — rust reference of the
/// Pallas kernel.
pub fn prox_group_lasso_rows(a: &Matrix, thresh: f32) -> Matrix {
    let mut out = a.clone();
    for r in 0..a.rows() {
        let norm: f32 = a.row(r).iter().map(|&v| v * v).sum::<f32>().sqrt();
        let scale = if norm > 0.0 { (1.0 - thresh / norm).max(0.0) } else { 0.0 };
        for v in out.row_mut(r) {
            *v *= scale;
        }
    }
    out
}

/// Columns whose l2 norm is at most `eps` are considered pruned.
pub fn active_columns(w: &Matrix, eps: f32) -> Vec<usize> {
    w.col_norms()
        .iter()
        .enumerate()
        .filter(|(_, &n)| n > eps)
        .map(|(i, _)| i)
        .collect()
}

/// 0/1 mask over columns (artifact input `colmask`).
pub fn column_mask(w: &Matrix, eps: f32) -> Vec<f32> {
    w.col_norms().iter().map(|&n| if n > eps { 1.0 } else { 0.0 }).collect()
}

/// Result of physically removing pruned columns.
#[derive(Clone, Debug)]
pub struct CompactedLayer {
    /// dense matrix over the surviving inputs
    pub weights: Matrix,
    /// original column index of each surviving column
    pub kept: Vec<usize>,
}

/// Drop pruned columns; the caller must gather the matching input
/// features (`kept`) at inference time — free on FPGAs (wiring).
pub fn compact_columns(w: &Matrix, eps: f32) -> CompactedLayer {
    let kept = active_columns(w, eps);
    CompactedLayer { weights: w.select_cols(&kept), kept }
}

/// Sparsity statistics for reporting.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PruneStats {
    pub total_columns: usize,
    pub active_columns: usize,
}

impl PruneStats {
    pub fn of(w: &Matrix, eps: f32) -> Self {
        PruneStats {
            total_columns: w.cols(),
            active_columns: active_columns(w, eps).len(),
        }
    }

    pub fn sparsity(&self) -> f64 {
        1.0 - self.active_columns as f64 / self.total_columns.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn prox_matches_closed_form() {
        // row norm 5 (3-4-0), thresh 1 => scale 0.8
        let a = Matrix::from_rows(&[&[3.0, 4.0, 0.0], &[0.0, 0.0, 0.0]]);
        let out = prox_group_lasso_rows(&a, 1.0);
        assert!((out.at(0, 0) - 2.4).abs() < 1e-6);
        assert!((out.at(0, 1) - 3.2).abs() < 1e-6);
        assert_eq!(out.row(1), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn prox_zeroes_small_rows() {
        let a = Matrix::from_rows(&[&[0.1, 0.1], &[5.0, 5.0]]);
        let out = prox_group_lasso_rows(&a, 1.0);
        assert_eq!(out.row(0), &[0.0, 0.0]);
        assert!(out.at(1, 0) > 0.0);
    }

    #[test]
    fn prox_zero_threshold_identity() {
        let mut rng = Rng::new(0);
        let a = Matrix::randn(6, 4, 1.0, &mut rng);
        assert_eq!(prox_group_lasso_rows(&a, 0.0), a);
    }

    #[test]
    fn compaction_keeps_only_active() {
        let w = Matrix::from_rows(&[&[1.0, 0.0, 2.0], &[1.0, 0.0, -1.0]]);
        let c = compact_columns(&w, 1e-6);
        assert_eq!(c.kept, vec![0, 2]);
        assert_eq!(c.weights, Matrix::from_rows(&[&[1.0, 2.0], &[1.0, -1.0]]));
    }

    #[test]
    fn compacted_product_matches_masked_product() {
        let mut rng = Rng::new(1);
        let mut w = Matrix::randn(5, 8, 1.0, &mut rng);
        for r in 0..5 {
            w.row_mut(r)[2] = 0.0;
            w.row_mut(r)[6] = 0.0;
        }
        let c = compact_columns(&w, 1e-9);
        let x: Vec<f32> = rng.normal_vec(8, 1.0);
        let x_kept: Vec<f32> = c.kept.iter().map(|&i| x[i]).collect();
        let y_full = w.matvec(&x);
        let y_comp = c.weights.matvec(&x_kept);
        for (a, b) in y_full.iter().zip(&y_comp) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn stats_sparsity() {
        let w = Matrix::from_rows(&[&[1.0, 0.0, 0.0, 2.0]]);
        let s = PruneStats::of(&w, 1e-9);
        assert_eq!(s.active_columns, 2);
        assert!((s.sparsity() - 0.5).abs() < 1e-12);
    }
}
