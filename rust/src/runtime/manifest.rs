//! `artifacts/manifest.tsv` parser — the contract between `aot.py` and
//! the rust runtime. Line format:
//!
//! ```text
//! artifact <name> <file>
//! in       <arg>  <f32|i32> <d0,d1,...>
//! out      <name> <f32|i32> <dims>
//! ```

use anyhow::{bail, Context, Result};
use std::path::Path;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype {other}"),
        }
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    /// Index of the input with the given argument name.
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|t| t.name == name)
    }
}

fn parse_dims(s: &str) -> Result<Vec<usize>> {
    if s.trim().is_empty() {
        return Ok(vec![]);
    }
    s.split(',')
        .map(|d| d.trim().parse::<usize>().context("dim parse"))
        .collect()
}

/// Parse a manifest file into artifact specs.
pub fn load_manifest(path: &Path) -> Result<Vec<ArtifactSpec>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read {}", path.display()))?;
    let mut specs: Vec<ArtifactSpec> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split('\t').collect();
        match fields.as_slice() {
            ["artifact", name, file] => specs.push(ArtifactSpec {
                name: name.to_string(),
                file: file.to_string(),
                inputs: vec![],
                outputs: vec![],
            }),
            ["in", name, dt, dims] => {
                let spec = specs
                    .last_mut()
                    .with_context(|| format!("line {}: in before artifact", lineno + 1))?;
                spec.inputs.push(TensorSpec {
                    name: name.to_string(),
                    dtype: DType::parse(dt)?,
                    dims: parse_dims(dims)?,
                });
            }
            ["out", name, dt, dims] => {
                let spec = specs
                    .last_mut()
                    .with_context(|| format!("line {}: out before artifact", lineno + 1))?;
                spec.outputs.push(TensorSpec {
                    name: name.to_string(),
                    dtype: DType::parse(dt)?,
                    dims: parse_dims(dims)?,
                });
            }
            other => bail!("line {}: unrecognized row {other:?}", lineno + 1),
        }
    }
    Ok(specs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_tmp(content: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!(
            "lccnn-manifest-{}-{}.tsv",
            std::process::id(),
            content.len()
        ));
        std::fs::write(&p, content).unwrap();
        p
    }

    #[test]
    fn parses_artifacts() {
        let p = write_tmp(
            "artifact\tmlp_fwd\tmlp_fwd.hlo.txt\nin\tW1\tf32\t300,784\nin\tx\tf32\t32,784\nout\tlogits\tf32\t32,10\n",
        );
        let specs = load_manifest(&p).unwrap();
        assert_eq!(specs.len(), 1);
        let s = &specs[0];
        assert_eq!(s.name, "mlp_fwd");
        assert_eq!(s.inputs.len(), 2);
        assert_eq!(s.inputs[0].dims, vec![300, 784]);
        assert_eq!(s.inputs[0].numel(), 235_200);
        assert_eq!(s.outputs[0].dtype, DType::F32);
        assert_eq!(s.input_index("x"), Some(1));
        assert_eq!(s.input_index("nope"), None);
    }

    #[test]
    fn rejects_orphan_rows() {
        let p = write_tmp("in\tx\tf32\t3\n");
        assert!(load_manifest(&p).is_err());
    }

    #[test]
    fn rejects_unknown_dtype() {
        let p = write_tmp("artifact\ta\ta.hlo\nin\tx\tf64\t3\n");
        assert!(load_manifest(&p).is_err());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.tsv");
        if !path.exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let specs = load_manifest(&path).unwrap();
        let names: Vec<&str> = specs.iter().map(|s| s.name.as_str()).collect();
        for expected in ["mlp_train_step", "mlp_eval", "mlp_fwd", "resnet_eval"] {
            assert!(names.contains(&expected), "missing {expected}");
        }
    }
}
