//! Host tensor <-> xla::Literal bridge.

use super::manifest::{DType, TensorSpec};
use anyhow::{anyhow, bail, Result};

/// A host-side tensor in the two dtypes the artifacts use.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32(Vec<usize>, Vec<f32>),
    I32(Vec<usize>, Vec<i32>),
}

impl HostTensor {
    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32(vec![1], vec![v])
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            HostTensor::F32(d, _) => d,
            HostTensor::I32(d, _) => d,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32(..) => DType::F32,
            HostTensor::I32(..) => DType::I32,
        }
    }

    pub fn numel(&self) -> usize {
        self.dims().iter().product()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(_, v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(_, v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    /// First element as f64 (loss scalars etc.).
    pub fn first(&self) -> f64 {
        match self {
            HostTensor::F32(_, v) => v.first().copied().unwrap_or(0.0) as f64,
            HostTensor::I32(_, v) => v.first().copied().unwrap_or(0) as f64,
        }
    }

    /// Build a rank-2 `[padded_rows, dim]` f32 tensor from sample rows,
    /// zero-padding to `padded_rows` (the serve layer's bridge from
    /// request batches to fixed-batch artifacts).
    pub fn from_rows_padded(rows: &[Vec<f32>], padded_rows: usize, dim: usize) -> Result<Self> {
        if rows.len() > padded_rows {
            bail!("{} rows exceed padded batch {padded_rows}", rows.len());
        }
        let mut flat = vec![0.0f32; padded_rows * dim];
        for (i, row) in rows.iter().enumerate() {
            if row.len() != dim {
                bail!("row {i}: {} values, want {dim}", row.len());
            }
            flat[i * dim..(i + 1) * dim].copy_from_slice(row);
        }
        Ok(HostTensor::F32(vec![padded_rows, dim], flat))
    }

    /// Split a rank-2 f32 tensor into its sample rows — the bridge from
    /// artifact outputs to the `exec`/serve per-sample representation.
    pub fn to_rows(&self) -> Result<Vec<Vec<f32>>> {
        self.to_rows_first(usize::MAX)
    }

    /// Like [`HostTensor::to_rows`] but converts only the first `n` rows
    /// (cheaply dropping batch padding instead of materializing it).
    pub fn to_rows_first(&self, n: usize) -> Result<Vec<Vec<f32>>> {
        match self {
            HostTensor::F32(dims, data) if dims.len() == 2 && dims[1] > 0 => {
                if data.len() != dims[0] * dims[1] {
                    bail!("inconsistent tensor: {} values for dims {dims:?}", data.len());
                }
                Ok(data.chunks(dims[1]).take(n).map(|c| c.to_vec()).collect())
            }
            _ => bail!("expected rank-2 f32 tensor, got {:?} {:?}", self.dtype(), self.dims()),
        }
    }

    pub fn validate(&self, spec: &TensorSpec) -> Result<()> {
        if self.dtype() != spec.dtype {
            bail!("dtype mismatch: got {:?}, want {:?}", self.dtype(), spec.dtype);
        }
        if self.dims() != spec.dims.as_slice() {
            bail!("shape mismatch: got {:?}, want {:?}", self.dims(), spec.dims);
        }
        Ok(())
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims_i64: Vec<i64> = self.dims().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32(_, v) => xla::Literal::vec1(v),
            HostTensor::I32(_, v) => xla::Literal::vec1(v),
        };
        lit.reshape(&dims_i64).map_err(|e| anyhow!("reshape literal: {e:?}"))
    }

    pub fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Self> {
        Ok(match spec.dtype {
            DType::F32 => {
                let v = lit.to_vec::<f32>().map_err(|e| anyhow!("literal->f32: {e:?}"))?;
                if v.len() != spec.numel() {
                    bail!("{}: size {} != {}", spec.name, v.len(), spec.numel());
                }
                HostTensor::F32(spec.dims.clone(), v)
            }
            DType::I32 => {
                let v = lit.to_vec::<i32>().map_err(|e| anyhow!("literal->i32: {e:?}"))?;
                if v.len() != spec.numel() {
                    bail!("{}: size {} != {}", spec.name, v.len(), spec.numel());
                }
                HostTensor::I32(spec.dims.clone(), v)
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, dtype: DType, dims: Vec<usize>) -> TensorSpec {
        TensorSpec { name: name.into(), dtype, dims }
    }

    #[test]
    fn validate_accepts_matching() {
        let t = HostTensor::F32(vec![2, 3], vec![0.0; 6]);
        assert!(t.validate(&spec("x", DType::F32, vec![2, 3])).is_ok());
    }

    #[test]
    fn validate_rejects_mismatches() {
        let t = HostTensor::F32(vec![2, 3], vec![0.0; 6]);
        assert!(t.validate(&spec("x", DType::I32, vec![2, 3])).is_err());
        assert!(t.validate(&spec("x", DType::F32, vec![3, 2])).is_err());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::F32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit, &spec("x", DType::F32, vec![2, 2])).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = HostTensor::I32(vec![3], vec![-1, 0, 7]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit, &spec("y", DType::I32, vec![3])).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_helper() {
        let t = HostTensor::scalar_f32(0.5);
        assert_eq!(t.dims(), &[1]);
        assert_eq!(t.first(), 0.5);
    }

    #[test]
    fn rows_roundtrip_with_padding() {
        let rows = vec![vec![1.0f32, 2.0], vec![3.0, 4.0]];
        let t = HostTensor::from_rows_padded(&rows, 3, 2).unwrap();
        assert_eq!(t.dims(), &[3, 2]);
        let back = t.to_rows().unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back[0], rows[0]);
        assert_eq!(back[1], rows[1]);
        assert_eq!(back[2], vec![0.0, 0.0]); // padding
        let first = t.to_rows_first(2).unwrap();
        assert_eq!(first, rows, "to_rows_first drops the padding rows");
        assert!(HostTensor::from_rows_padded(&rows, 1, 2).is_err());
        assert!(HostTensor::from_rows_padded(&rows, 4, 3).is_err());
        assert!(HostTensor::I32(vec![2, 2], vec![0; 4]).to_rows().is_err());
    }
}
