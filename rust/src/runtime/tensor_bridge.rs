//! Host tensor <-> xla::Literal bridge.

use super::manifest::{DType, TensorSpec};
use anyhow::{anyhow, bail, Result};

/// A host-side tensor in the two dtypes the artifacts use.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32(Vec<usize>, Vec<f32>),
    I32(Vec<usize>, Vec<i32>),
}

impl HostTensor {
    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32(vec![1], vec![v])
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            HostTensor::F32(d, _) => d,
            HostTensor::I32(d, _) => d,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32(..) => DType::F32,
            HostTensor::I32(..) => DType::I32,
        }
    }

    pub fn numel(&self) -> usize {
        self.dims().iter().product()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(_, v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(_, v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    /// First element as f64 (loss scalars etc.).
    pub fn first(&self) -> f64 {
        match self {
            HostTensor::F32(_, v) => v.first().copied().unwrap_or(0.0) as f64,
            HostTensor::I32(_, v) => v.first().copied().unwrap_or(0) as f64,
        }
    }

    pub fn validate(&self, spec: &TensorSpec) -> Result<()> {
        if self.dtype() != spec.dtype {
            bail!("dtype mismatch: got {:?}, want {:?}", self.dtype(), spec.dtype);
        }
        if self.dims() != spec.dims.as_slice() {
            bail!("shape mismatch: got {:?}, want {:?}", self.dims(), spec.dims);
        }
        Ok(())
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims_i64: Vec<i64> = self.dims().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32(_, v) => xla::Literal::vec1(v),
            HostTensor::I32(_, v) => xla::Literal::vec1(v),
        };
        lit.reshape(&dims_i64).map_err(|e| anyhow!("reshape literal: {e:?}"))
    }

    pub fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Self> {
        Ok(match spec.dtype {
            DType::F32 => {
                let v = lit.to_vec::<f32>().map_err(|e| anyhow!("literal->f32: {e:?}"))?;
                if v.len() != spec.numel() {
                    bail!("{}: size {} != {}", spec.name, v.len(), spec.numel());
                }
                HostTensor::F32(spec.dims.clone(), v)
            }
            DType::I32 => {
                let v = lit.to_vec::<i32>().map_err(|e| anyhow!("literal->i32: {e:?}"))?;
                if v.len() != spec.numel() {
                    bail!("{}: size {} != {}", spec.name, v.len(), spec.numel());
                }
                HostTensor::I32(spec.dims.clone(), v)
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, dtype: DType, dims: Vec<usize>) -> TensorSpec {
        TensorSpec { name: name.into(), dtype, dims }
    }

    #[test]
    fn validate_accepts_matching() {
        let t = HostTensor::F32(vec![2, 3], vec![0.0; 6]);
        assert!(t.validate(&spec("x", DType::F32, vec![2, 3])).is_ok());
    }

    #[test]
    fn validate_rejects_mismatches() {
        let t = HostTensor::F32(vec![2, 3], vec![0.0; 6]);
        assert!(t.validate(&spec("x", DType::I32, vec![2, 3])).is_err());
        assert!(t.validate(&spec("x", DType::F32, vec![3, 2])).is_err());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::F32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit, &spec("x", DType::F32, vec![2, 2])).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = HostTensor::I32(vec![3], vec![-1, 0, 7]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit, &spec("y", DType::I32, vec![3])).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_helper() {
        let t = HostTensor::scalar_f32(0.5);
        assert_eq!(t.dims(), &[1]);
        assert_eq!(t.first(), 0.5);
    }
}
