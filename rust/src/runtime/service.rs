//! Thread-confined PJRT service.
//!
//! The `xla` crate's client/executable types are `!Send` (Rc internals),
//! so multi-threaded users (the serving layer) talk to a dedicated
//! runtime thread over channels. anyhow::Error is Send+Sync, so errors
//! propagate cleanly.

use super::{HostTensor, Runtime};
use anyhow::{anyhow, Result};
use std::path::PathBuf;
use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;
use std::thread::JoinHandle;

enum Call {
    Run { name: String, inputs: Vec<HostTensor>, resp: Sender<Result<Vec<HostTensor>>> },
    Shutdown,
}

/// Send+Sync handle to a runtime living on its own thread.
pub struct PjrtService {
    tx: Mutex<Sender<Call>>,
    handle: Option<JoinHandle<()>>,
}

impl PjrtService {
    /// Spawn the runtime thread on the given artifact directory. Blocks
    /// until the runtime has opened (or failed to open).
    pub fn start(dir: PathBuf) -> Result<Self> {
        let (tx, rx) = channel::<Call>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("lccnn-pjrt".into())
            .spawn(move || {
                let rt = match Runtime::open(&dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                for call in rx {
                    match call {
                        Call::Run { name, inputs, resp } => {
                            let result = rt.get(&name).and_then(|exe| exe.run(&inputs));
                            let _ = resp.send(result);
                        }
                        Call::Shutdown => break,
                    }
                }
            })
            .expect("spawn pjrt thread");
        ready_rx.recv().map_err(|_| anyhow!("pjrt thread died during open"))??;
        Ok(PjrtService { tx: Mutex::new(tx), handle: Some(handle) })
    }

    /// Start on the default artifact directory.
    pub fn start_default() -> Result<Self> {
        let dir = std::env::var("LCCNN_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
        Self::start(dir)
    }

    /// Execute an artifact by name (blocking).
    pub fn call(&self, name: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let (resp_tx, resp_rx) = channel();
        self.tx
            .lock()
            .unwrap()
            .send(Call::Run { name: name.to_string(), inputs, resp: resp_tx })
            .map_err(|_| anyhow!("pjrt thread gone"))?;
        resp_rx.recv().map_err(|_| anyhow!("pjrt thread dropped response"))?
    }
}

impl Drop for PjrtService {
    fn drop(&mut self) {
        let _ = self.tx.lock().unwrap().send(Call::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
