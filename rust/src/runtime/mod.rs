//! PJRT runtime: loads the AOT-lowered HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once on the CPU PJRT client and
//! executes them from the rust hot path. Python is never invoked at
//! runtime — the manifest + HLO text files are the entire contract.
//!
//! Interchange is HLO *text* (not serialized protos): jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

mod manifest;
mod service;
mod tensor_bridge;

pub use manifest::{load_manifest, ArtifactSpec, DType, TensorSpec};
pub use service::PjrtService;
pub use tensor_bridge::HostTensor;

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// A compiled artifact: PJRT executable + its manifest signature.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub spec: ArtifactSpec,
}

impl Executable {
    /// Execute with host tensors; validates shapes/dtypes against the
    /// manifest and unpacks the output tuple.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, spec) in inputs.iter().zip(&self.spec.inputs) {
            t.validate(spec).with_context(|| {
                format!("{}: input {}", self.spec.name, spec.name)
            })?;
            literals.push(t.to_literal()?);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("execute {}", self.spec.name))?;
        let tuple = result
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .ok_or_else(|| anyhow!("{}: empty result", self.spec.name))?
            .to_literal_sync()?
            .to_tuple()?;
        if tuple.len() != self.spec.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.spec.name,
                self.spec.outputs.len(),
                tuple.len()
            );
        }
        tuple
            .into_iter()
            .zip(&self.spec.outputs)
            .map(|(lit, spec)| HostTensor::from_literal(&lit, spec))
            .collect()
    }

    /// Execute with pre-built literals, returning literals (perf path:
    /// training state stays in literal form across steps instead of
    /// round-tripping through host vectors — see EXPERIMENTS.md §Perf).
    /// Only arity is validated; shape errors surface from PJRT itself.
    pub fn run_literals(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let result = self
            .exe
            .execute::<&xla::Literal>(inputs)
            .with_context(|| format!("execute {}", self.spec.name))?;
        let tuple = result
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .ok_or_else(|| anyhow!("{}: empty result", self.spec.name))?
            .to_literal_sync()?
            .to_tuple()?;
        if tuple.len() != self.spec.outputs.len() {
            bail!("{}: bad output arity {}", self.spec.name, tuple.len());
        }
        Ok(tuple)
    }
}

/// Artifact registry: one PJRT client, lazily compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    specs: HashMap<String, ArtifactSpec>,
    compiled: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Runtime {
    /// Open the artifact directory (must contain manifest.tsv).
    pub fn open(dir: &Path) -> Result<Self> {
        let specs = load_manifest(&dir.join("manifest.tsv"))?
            .into_iter()
            .map(|s| (s.name.clone(), s))
            .collect();
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client, dir: dir.to_path_buf(), specs, compiled: Mutex::new(HashMap::new()) })
    }

    /// Default artifact location (repo-root/artifacts), honoring
    /// `LCCNN_ARTIFACTS`.
    pub fn open_default() -> Result<Self> {
        let dir = std::env::var("LCCNN_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
        Self::open(&dir)
    }

    pub fn artifact_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.specs.keys().cloned().collect();
        names.sort();
        names
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (once) and return the named artifact.
    pub fn get(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.compiled.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .specs
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}; have {:?}", self.artifact_names()))?
            .clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        let executable = std::sync::Arc::new(Executable { exe, spec });
        self.compiled.lock().unwrap().insert(name.to_string(), executable.clone());
        Ok(executable)
    }
}
