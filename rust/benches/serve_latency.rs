//! SRV bench: serving latency/throughput, compressed shift-add VM vs
//! dense PJRT backend, across offered concurrency — including
//! sharded-vs-unsharded and float-vs-fixed rows for the recipe-served
//! `PipelineExecutor`.
//!
//!     cargo bench --bench serve_latency
//!
//! CI smoke: `LCCNN_BENCH_QUICK=1` shrinks the request count;
//! `LCCNN_BENCH_JSON=BENCH_exec.json` appends one JSON row per table row.

use lccnn::cluster::affinity::{cluster_columns, AffinityParams};
use lccnn::compress::{demo_network, NetworkPipeline, Pipeline, Recipe};
use lccnn::config::{ExecConfig, ExecMode, PoolMode, ServeConfig, ShardMode, ShardSpec};
use lccnn::exec::{even_ranges, remote_sharded_executor, Executor, RemoteOptions, ShardWorker};
use lccnn::lcc::LccConfig;
use lccnn::metrics::Metrics;
use lccnn::nn::compressed::{CompressedMlp, Layer1};
use lccnn::nn::mlp::MlpParams;
use lccnn::pipeline::mlp::synthetic_reg_weights;
use lccnn::prune::compact_columns;
use lccnn::report::Table;
use lccnn::runtime::{HostTensor, PjrtService};
use lccnn::serve::{
    BatchEvaluator, CompressedMlpBackend, ExecutorBackend, MutexEvaluator, PjrtMlpBackend, Server,
};
use lccnn::share::SharedLayer;
use lccnn::util::{bench, Rng};
use std::sync::Arc;
use std::time::Instant;

fn compressed_model(params: &MlpParams, exec: ExecConfig) -> CompressedMlp {
    let w1 = synthetic_reg_weights(0, 120);
    let compact = compact_columns(&w1, 1e-6);
    let clustering = cluster_columns(&compact.weights, &AffinityParams::default());
    let shared = SharedLayer::from_clustering(&compact.weights, &clustering);
    CompressedMlp {
        kept: compact.kept,
        layer1: Layer1::SharedLcc(shared.with_lcc_exec(&LccConfig::fs(), exec)),
        b1: params.b1.clone(),
        w2: params.w2.clone(),
        b2: params.b2.clone(),
    }
}

/// Engine tuning that parallelizes at serving batch sizes, so the two
/// dispatch modes (per-call scoped spawns vs the persistent pool) are
/// actually exercised on the latency path — exactly the workload the
/// pool exists for. chunk 4 so a batch of 8 already splits into 2
/// parallel chunks (chunk parallelism needs n_chunks > 1; burst 1 stays
/// serial in both modes by construction).
fn serving_exec(mode: PoolMode) -> ExecConfig {
    ExecConfig { chunk: 4, parallel_min_batch: 8, pool_mode: mode, ..ExecConfig::default() }
}

fn run(backend: Arc<dyn BatchEvaluator>, name: &str, burst: usize, n: usize, t: &mut Table) {
    let server =
        Server::start(backend, ServeConfig { batch_timeout_us: 150, ..Default::default() });
    let mut rng = Rng::new(42);
    let start = Instant::now();
    let mut done = 0usize;
    while done < n {
        let b = burst.min(n - done);
        let rxs: Vec<_> = (0..b).map(|_| server.submit(rng.normal_vec(784, 1.0))).collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        done += b;
    }
    let thpt = n as f64 / start.elapsed().as_secs_f64();
    let s = server.shutdown();
    t.add_row(vec![
        name.into(),
        burst.to_string(),
        format!("{thpt:.0}"),
        format!("{:.0}", s.p50_latency_us),
        format!("{:.0}", s.p99_latency_us),
        format!("{:.1}", s.mean_batch_size),
    ]);
    bench::emit(
        "serve_latency",
        &[
            ("backend", name.to_string()),
            ("burst", burst.to_string()),
            ("req_per_s", format!("{thpt:.1}")),
            ("p50_us", format!("{:.1}", s.p50_latency_us)),
            ("p99_us", format!("{:.1}", s.p99_latency_us)),
            ("mean_batch", format!("{:.2}", s.mean_batch_size)),
        ],
    );
}

fn main() {
    lccnn::util::logger::init();
    let params = MlpParams::init(0);
    let n = bench::pick(300, 3000);
    let mut t = Table::new(
        "serving: compressed VM vs dense PJRT under bursty load",
        &["backend", "burst", "req/s", "p50 us", "p99 us", "mean batch"],
    );
    for burst in [1usize, 8, 32] {
        let model = Arc::new(compressed_model(&params, serving_exec(PoolMode::Persistent)));
        run(Arc::new(CompressedMlpBackend { model }), "compressed-exec/pool", burst, n, &mut t);
    }
    for burst in [1usize, 8, 32] {
        let model = Arc::new(compressed_model(&params, serving_exec(PoolMode::Scoped)));
        run(Arc::new(CompressedMlpBackend { model }), "compressed-exec/scoped", burst, n, &mut t);
    }
    // sharded vs unsharded serve of the same recipe artifact: the full
    // PipelineExecutor (gather kept -> segment sums -> LCC engine), with
    // the engine split across 1/2/4 output-range shards
    for shards in [1usize, 2, 4] {
        let mut recipe = Recipe { exec: serving_exec(PoolMode::Persistent), ..Recipe::default() };
        if shards > 1 {
            recipe.shard = Some(ShardSpec { shards, mode: ShardMode::Parallel });
        }
        let w1 = synthetic_reg_weights(0, 120);
        let model =
            Pipeline::from_recipe(&recipe).expect("valid recipe").run(&w1).expect("pipeline runs");
        let exec: Arc<dyn Executor> = Arc::new(model.into_executor());
        let name = if shards == 1 {
            "pipeline-exec/unsharded".to_string()
        } else {
            format!("pipeline-exec/shard{shards}")
        };
        for burst in [1usize, 8, 32] {
            run(Arc::new(ExecutorBackend::new(Arc::clone(&exec), 64)), &name, burst, n, &mut t);
        }
    }
    // the same recipe artifact served on the fixed-point shift-add
    // engine: float-vs-fixed latency on the identical lowered program
    {
        let exec = ExecConfig { exec_mode: ExecMode::Fixed, ..serving_exec(PoolMode::Persistent) };
        let recipe = Recipe { exec, ..Recipe::default() };
        let w1 = synthetic_reg_weights(0, 120);
        let px = Pipeline::from_recipe(&recipe)
            .expect("valid recipe")
            .run(&w1)
            .expect("pipeline runs")
            .into_executor();
        assert!(px.is_fixed(), "fixed lowering fell back to float");
        let exec: Arc<dyn Executor> = Arc::new(px);
        for burst in [1usize, 8, 32] {
            let backend = Arc::new(ExecutorBackend::new(Arc::clone(&exec), 64));
            run(backend, "pipeline-exec/fixed", burst, n, &mut t);
        }
    }
    // the full-network chained engine: a LeNet-300-100-shaped 3-layer
    // MLP (784-300-100-10) compressed per layer and served as one
    // NetworkExecutor — the layer-chaining tax (bias + activation
    // kernels, ping-pong lane buffers) on the same latency path as the
    // single-matrix pipeline-exec rows
    {
        let recipe = Recipe { exec: serving_exec(PoolMode::Persistent), ..Recipe::default() };
        let ckpt = demo_network(&[784, 300, 100, 10], 0);
        let net = NetworkPipeline::from_recipe(&recipe)
            .expect("valid recipe")
            .run(&ckpt)
            .expect("network pipeline runs");
        let exec: Arc<dyn Executor> = Arc::new(net.into_executor().expect("network engine"));
        for burst in [1usize, 8, 32] {
            let backend = Arc::new(ExecutorBackend::new(Arc::clone(&exec), 64));
            run(backend, "pipeline-exec/mlp3", burst, n, &mut t);
        }
    }
    // the same artifact split across two in-process shard-worker TCP
    // servers on loopback, gathered by RemoteExecutors — the wire tax
    // of distributed serving vs the in-process sharded rows above
    {
        let recipe = Recipe { exec: serving_exec(PoolMode::Persistent), ..Recipe::default() };
        let w1 = synthetic_reg_weights(0, 120);
        let model =
            Pipeline::from_recipe(&recipe).expect("valid recipe").run(&w1).expect("pipeline runs");
        let cuts = even_ranges(w1.rows(), 2);
        let workers: Vec<ShardWorker> = cuts
            .iter()
            .map(|r| {
                let e = model.range_executor(r.clone()).expect("range executor");
                ShardWorker::spawn(Arc::new(e), r.clone(), ExecMode::Float, "127.0.0.1:0")
                    .expect("spawn shard worker")
            })
            .collect();
        let addrs: Vec<String> = workers.iter().map(|w| w.addr().to_string()).collect();
        let remote = remote_sharded_executor(
            &addrs,
            RemoteOptions::default(),
            serving_exec(PoolMode::Persistent),
            Arc::new(Metrics::new()),
        )
        .expect("connect remote shards");
        let exec: Arc<dyn Executor> = Arc::new(remote);
        for burst in [1usize, 8, 32] {
            let backend = Arc::new(ExecutorBackend::new(Arc::clone(&exec), 64));
            run(backend, "pipeline-exec/remote2", burst, n, &mut t);
        }
        drop(exec);
        drop(workers);
    }
    // the same split with two replica workers per output range (4
    // workers total): the replication tax — the client-side failover
    // layer sitting on the hot path even while every replica is healthy
    // — vs the plain remote2 rows above
    {
        let recipe = Recipe { exec: serving_exec(PoolMode::Persistent), ..Recipe::default() };
        let w1 = synthetic_reg_weights(0, 120);
        let model =
            Pipeline::from_recipe(&recipe).expect("valid recipe").run(&w1).expect("pipeline runs");
        let cuts = even_ranges(w1.rows(), 2);
        let workers: Vec<ShardWorker> = cuts
            .iter()
            .flat_map(|r| [r.clone(), r.clone()]) // two replicas per range
            .map(|r| {
                let e = model.range_executor(r.clone()).expect("range executor");
                ShardWorker::spawn(Arc::new(e), r, ExecMode::Float, "127.0.0.1:0")
                    .expect("spawn shard worker")
            })
            .collect();
        let addrs: Vec<String> = workers.iter().map(|w| w.addr().to_string()).collect();
        let remote = remote_sharded_executor(
            &addrs,
            RemoteOptions::default(),
            serving_exec(PoolMode::Persistent),
            Arc::new(Metrics::new()),
        )
        .expect("connect remote replicas");
        assert_eq!(remote.num_shards(), 2, "replicas must group per range");
        let exec: Arc<dyn Executor> = Arc::new(remote);
        for burst in [1usize, 8, 32] {
            let backend = Arc::new(ExecutorBackend::new(Arc::clone(&exec), 64));
            run(backend, "pipeline-exec/remote2-replica", burst, n, &mut t);
        }
        drop(exec);
        drop(workers);
    }
    // the pre-exec-engine behaviour (forward_one per sample) for comparison
    for burst in [1usize, 8, 32] {
        let model = Arc::new(compressed_model(&params, ExecConfig::default()));
        let scalar = MutexEvaluator::new(
            move |xs: &[Vec<f32>]| Ok(xs.iter().map(|x| model.forward_one(x)).collect()),
            64,
            "compressed-scalar",
        );
        run(Arc::new(scalar), "compressed-scalar", burst, n, &mut t);
    }
    match PjrtService::start_default() {
        Ok(service) => {
            let service = Arc::new(service);
            for burst in [1usize, 8, 32] {
                let host_params = vec![
                    HostTensor::F32(vec![300, 784], params.w1.data().to_vec()),
                    HostTensor::F32(vec![300], params.b1.clone()),
                    HostTensor::F32(vec![10, 300], params.w2.data().to_vec()),
                    HostTensor::F32(vec![10], params.b2.clone()),
                ];
                let backend: Arc<dyn BatchEvaluator> =
                    Arc::new(PjrtMlpBackend::new(Arc::clone(&service), host_params, 32));
                run(backend, "dense-pjrt", burst, n, &mut t);
            }
        }
        Err(e) => eprintln!("dense-pjrt rows skipped: {e:#}"),
    }
    println!("{}", t.render());
    println!("compressed-exec rows parallelize at serving batches (chunk 4,");
    println!("min batch 8, so batches of 8+ split into 2+ chunks): /pool");
    println!("dispatches on the persistent worker pool, /scoped spawns+joins");
    println!("threads per batch — their delta is the per-call spawn tax on");
    println!("the latency path. burst 1 rows are serial in both modes.");
    println!("pipeline-exec rows serve the same recipe artifact unsharded vs");
    println!("split across 2/4 output-range shards (sharded scatter/gather on");
    println!("the worker pool) — the sharded-vs-unsharded serving comparison");
    println!("for EXPERIMENTS.md §Sharding; outputs are bit-identical.");
    println!("pipeline-exec/fixed serves the same artifact on the integer");
    println!("shift-add datapath (exec_mode = fixed) — the float-vs-fixed");
    println!("latency comparison for EXPERIMENTS.md §Perf.");
    println!("pipeline-exec/mlp3 serves a 3-layer 784-300-100-10 network as");
    println!("one chained NetworkExecutor (per-layer engines + bias/ReLU");
    println!("kernels, reused lane buffers) — the full-network serving row");
    println!("for EXPERIMENTS.md §Full-network.");
    println!("pipeline-exec/remote2 serves the artifact split across two");
    println!("shard-worker TCP servers on loopback (bit-identical gather) —");
    println!("the wire tax vs pipeline-exec/shard2 for EXPERIMENTS.md");
    println!("§Remote-shards. /remote2-replica doubles each range to two");
    println!("replica workers — the client-side failover layer's overhead");
    println!("on an all-healthy path (its win shows when a replica dies:");
    println!("zero sheds).");
    println!("worker pool after run: {:?}", lccnn::exec::global_pool().stats());
}
