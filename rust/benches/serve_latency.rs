//! SRV bench: serving latency/throughput, compressed shift-add VM vs
//! dense PJRT backend, across offered concurrency.
//!
//!     cargo bench --bench serve_latency

use lccnn::cluster::affinity::{cluster_columns, AffinityParams};
use lccnn::config::ServeConfig;
use lccnn::lcc::LccConfig;
use lccnn::nn::compressed::{CompressedMlp, Layer1};
use lccnn::nn::mlp::MlpParams;
use lccnn::pipeline::mlp::synthetic_reg_weights;
use lccnn::prune::compact_columns;
use lccnn::report::Table;
use lccnn::runtime::{HostTensor, PjrtService};
use lccnn::serve::{BatchEvaluator, CompressedMlpBackend, MutexEvaluator, PjrtMlpBackend, Server};
use lccnn::share::SharedLayer;
use lccnn::util::Rng;
use std::sync::Arc;
use std::time::Instant;

fn compressed_model(params: &MlpParams) -> CompressedMlp {
    let w1 = synthetic_reg_weights(0, 120);
    let compact = compact_columns(&w1, 1e-6);
    let clustering = cluster_columns(&compact.weights, &AffinityParams::default());
    let shared = SharedLayer::from_clustering(&compact.weights, &clustering);
    CompressedMlp {
        kept: compact.kept,
        layer1: Layer1::SharedLcc(shared.with_lcc(&LccConfig::fs())),
        b1: params.b1.clone(),
        w2: params.w2.clone(),
        b2: params.b2.clone(),
    }
}

fn run(backend: Arc<dyn BatchEvaluator>, name: &str, burst: usize, n: usize, t: &mut Table) {
    let server = Server::start(backend, ServeConfig { batch_timeout_us: 150, ..Default::default() });
    let mut rng = Rng::new(42);
    let start = Instant::now();
    let mut done = 0usize;
    while done < n {
        let b = burst.min(n - done);
        let rxs: Vec<_> = (0..b).map(|_| server.submit(rng.normal_vec(784, 1.0))).collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        done += b;
    }
    let thpt = n as f64 / start.elapsed().as_secs_f64();
    let s = server.shutdown();
    t.add_row(vec![
        name.into(),
        burst.to_string(),
        format!("{thpt:.0}"),
        format!("{:.0}", s.p50_latency_us),
        format!("{:.0}", s.p99_latency_us),
        format!("{:.1}", s.mean_batch_size),
    ]);
}

fn main() {
    lccnn::util::logger::init();
    let params = MlpParams::init(0);
    let n = 3000;
    let mut t = Table::new(
        "serving: compressed VM vs dense PJRT under bursty load",
        &["backend", "burst", "req/s", "p50 us", "p99 us", "mean batch"],
    );
    for burst in [1usize, 8, 32] {
        let model = Arc::new(compressed_model(&params));
        run(Arc::new(CompressedMlpBackend { model }), "compressed-exec", burst, n, &mut t);
    }
    // the pre-exec-engine behaviour (forward_one per sample) for comparison
    for burst in [1usize, 8, 32] {
        let model = Arc::new(compressed_model(&params));
        let scalar = MutexEvaluator::new(
            move |xs: &[Vec<f32>]| Ok(xs.iter().map(|x| model.forward_one(x)).collect()),
            64,
            "compressed-scalar",
        );
        run(Arc::new(scalar), "compressed-scalar", burst, n, &mut t);
    }
    match PjrtService::start_default() {
        Ok(service) => {
            let service = Arc::new(service);
            for burst in [1usize, 8, 32] {
                let host_params = vec![
                    HostTensor::F32(vec![300, 784], params.w1.data().to_vec()),
                    HostTensor::F32(vec![300], params.b1.clone()),
                    HostTensor::F32(vec![10, 300], params.w2.data().to_vec()),
                    HostTensor::F32(vec![10], params.b2.clone()),
                ];
                let backend: Arc<dyn BatchEvaluator> =
                    Arc::new(PjrtMlpBackend::new(Arc::clone(&service), host_params, 32));
                run(backend, "dense-pjrt", burst, n, &mut t);
            }
        }
        Err(e) => eprintln!("dense-pjrt rows skipped: {e:#}"),
    }
    println!("{}", t.render());
}
