//! ABL-VM bench: adder-graph execution throughput across the engine
//! family — naive interpreter, scalar plan (the old `CompiledGraph`
//! path), per-op vs run-grouped float dispatch, the fixed-point integer
//! engine, parallel engine and the sharded scatter/gather executor —
//! plus ASAP schedule stats (the FPGA parallelism proxy) on MLP-shaped
//! decompositions. Record the resulting table in EXPERIMENTS.md §Perf.
//!
//!     cargo bench --bench adder_vm
//!
//! CI smoke: `LCCNN_BENCH_QUICK=1` shrinks the batch/iteration counts;
//! `LCCNN_BENCH_JSON=BENCH_exec.json` appends one JSON row per table row.
#![allow(deprecated)]

use lccnn::config::{ExecConfig, PoolMode};
use lccnn::exec::{BatchEngine, ExecPlan, Executor, FixedEngine, ShardedExecutor};
use lccnn::graph::{schedule, CompiledGraph};
use lccnn::lcc::{decompose, LccConfig};
use lccnn::report::Table;
use lccnn::tensor::Matrix;
use lccnn::util::{bench, stats, timer, Rng};

/// per-sample microseconds for a whole-batch closure
fn per_sample_us(batch: usize, warmup: usize, iters: usize, mut f: impl FnMut()) -> f64 {
    let samples = timer::bench(warmup, iters, &mut f);
    stats::mean(&samples) * 1e6 / batch as f64
}

fn main() {
    let mut rng = Rng::new(0);
    let batch: usize = bench::pick(64, 512);
    let (warmup, iters) = (bench::pick(1, 3), bench::pick(3, 30));
    let mut t = Table::new(
        &format!("adder-graph execution, us/sample (batch {batch} for the engine columns)"),
        &["matrix", "algo", "adds", "depth", "max width", "interp", "scalar plan",
          "per-op x1", "batch x1", "fixed x1", "par scoped", "par pool", "pool speedup",
          "shard x2", "shard x4", "dense"],
    );
    for &(n, k) in &[(300usize, 30usize), (300, 60), (64, 9), (192, 3)] {
        let w = Matrix::randn(n, k, 0.5, &mut rng);
        let xs: Vec<Vec<f32>> = (0..batch).map(|_| rng.normal_vec(k, 1.0)).collect();
        let dense_us = per_sample_us(batch, warmup, iters, || {
            for x in &xs {
                std::hint::black_box(w.matvec(std::hint::black_box(x)));
            }
        });
        for (name, cfg) in [("fp", LccConfig::fp()), ("fs", LccConfig::fs())] {
            let d = decompose(&w, &cfg);
            let g = d.graph();
            let s = schedule(g);

            let interp_us = per_sample_us(batch, warmup, iters, || {
                for x in &xs {
                    std::hint::black_box(g.execute(std::hint::black_box(x)));
                }
            });

            // the seed CompiledGraph per-sample path (now an ExecPlan shim)
            let c = CompiledGraph::new(g);
            let mut scratch = Vec::new();
            let mut out = Vec::new();
            let scalar_us = per_sample_us(batch, warmup, iters, || {
                for x in &xs {
                    c.execute_into(std::hint::black_box(x), &mut scratch, &mut out);
                    std::hint::black_box(&out);
                }
            });

            // pre-specialization float dispatch: one coefficient load and
            // inner loop per op — the baseline the run grouping replaces
            let plan = ExecPlan::new(g);
            let mut lanes = Vec::new();
            let mut per_op_ys: Vec<Vec<f32>> = vec![Vec::new(); batch];
            let per_op_us = per_sample_us(batch, warmup, iters, || {
                plan.eval_lanes_per_op(std::hint::black_box(&xs), &mut lanes, &mut per_op_ys);
                std::hint::black_box(&per_op_ys);
            });

            let serial = BatchEngine::with_config(g, ExecConfig::serial());
            let mut ys = Vec::new();
            let batch_us = per_sample_us(batch, warmup, iters, || {
                serial.execute_batch_into(std::hint::black_box(&xs), &mut ys);
                std::hint::black_box(&ys);
            });

            // fixed-point shift-add datapath, same run-grouped dispatch:
            // integer shifts/adds instead of float multiply-accumulate
            let fixed = FixedEngine::with_config(g, ExecConfig::serial())
                .expect("LCC graphs are power-of-two scaled and must lower");
            let fixed_us = per_sample_us(batch, warmup, iters, || {
                fixed.execute_batch_into(std::hint::black_box(&xs), &mut ys);
                std::hint::black_box(&ys);
            });

            let scoped = BatchEngine::with_config(
                g,
                ExecConfig {
                    chunk: 64,
                    parallel_min_batch: 128,
                    pool_mode: PoolMode::Scoped,
                    ..ExecConfig::default()
                },
            );
            let scoped_us = per_sample_us(batch, warmup, iters, || {
                scoped.execute_batch_into(std::hint::black_box(&xs), &mut ys);
                std::hint::black_box(&ys);
            });

            let pooled = BatchEngine::with_config(
                g,
                ExecConfig {
                    chunk: 64,
                    parallel_min_batch: 128,
                    pool_mode: PoolMode::Persistent,
                    ..ExecConfig::default()
                },
            );
            let pooled_us = per_sample_us(batch, warmup, iters, || {
                pooled.execute_batch_into(std::hint::black_box(&xs), &mut ys);
                std::hint::black_box(&ys);
            });

            // sharded scatter/gather over the same program, serial inner
            // engines: the delta vs `batch x1` is the sharding layer +
            // cross-shard parallelism, not pool effects
            let shard_us: Vec<f64> = [2usize, 4]
                .iter()
                .map(|&shards| {
                    let engine = ShardedExecutor::from_graph(
                        g,
                        ExecConfig { shards, threads: 1, ..ExecConfig::default() },
                    );
                    per_sample_us(batch, warmup, iters, || {
                        engine.execute_batch_into(std::hint::black_box(&xs), &mut ys);
                        std::hint::black_box(&ys);
                    })
                })
                .collect();

            t.add_row(vec![
                format!("{n}x{k}"),
                name.into(),
                g.additions().to_string(),
                s.depth.to_string(),
                s.max_width.to_string(),
                format!("{interp_us:.2}"),
                format!("{scalar_us:.2}"),
                format!("{per_op_us:.2}"),
                format!("{batch_us:.2}"),
                format!("{fixed_us:.2}"),
                format!("{scoped_us:.2}"),
                format!("{pooled_us:.2}"),
                format!("{:.2}x", scoped_us / pooled_us.max(1e-9)),
                format!("{:.2}", shard_us[0]),
                format!("{:.2}", shard_us[1]),
                format!("{dense_us:.2}"),
            ]);
            bench::emit(
                "adder_vm",
                &[
                    ("matrix", format!("{n}x{k}")),
                    ("algo", name.to_string()),
                    ("adds", g.additions().to_string()),
                    ("batch", batch.to_string()),
                    ("interp_us", format!("{interp_us:.4}")),
                    ("scalar_us", format!("{scalar_us:.4}")),
                    ("per_op_us", format!("{per_op_us:.4}")),
                    ("batch_x1_us", format!("{batch_us:.4}")),
                    ("fixed_x1_us", format!("{fixed_us:.4}")),
                    ("par_scoped_us", format!("{scoped_us:.4}")),
                    ("par_pool_us", format!("{pooled_us:.4}")),
                    ("shard2_us", format!("{:.4}", shard_us[0])),
                    ("shard4_us", format!("{:.4}", shard_us[1])),
                    ("dense_us", format!("{dense_us:.4}")),
                ],
            );
        }
    }
    println!("{}", t.render());
    println!("interp = per-sample graph interpreter (oracle); scalar plan = seed");
    println!("CompiledGraph path; per-op x1 = lane-major float without run");
    println!("grouping (one coefficient dispatch per op); batch x1 = the same");
    println!("lanes with run-grouped dispatch (exec::BatchEngine, one thread);");
    println!("fixed x1 = exec::FixedEngine integer shift-add lanes, run-grouped,");
    println!("one thread; par scoped = chunks across per-call scoped threads; par");
    println!("pool = same chunks on the persistent worker pool (pool speedup =");
    println!("scoped/pool, the per-call spawn tax). shard xN = ShardedExecutor:");
    println!("the program split into N output-range sub-plans on serial inner");
    println!("engines, scatter/gather on the pool — vs batch x1 this isolates");
    println!("the sharding layer's cost/benefit. depth = FPGA pipeline");
    println!("latency in adder stages; max width = peak simultaneous adders.");
    println!("The addition count, not wall time, is the hardware cost model —");
    println!("the engine columns measure the *simulation/serving* hot path.");
    println!("worker pool after run: {:?}", lccnn::exec::global_pool().stats());
}
