//! ABL-VM bench: adder-graph execution throughput across the engine
//! family — naive interpreter, scalar plan (the old `CompiledGraph`
//! path), batch-major engine (1 thread) and parallel engine — plus ASAP
//! schedule stats (the FPGA parallelism proxy) on MLP-shaped
//! decompositions. Record the resulting table in EXPERIMENTS.md §Perf.
//!
//!     cargo bench --bench adder_vm
#![allow(deprecated)]

use lccnn::config::{ExecConfig, PoolMode};
use lccnn::exec::{BatchEngine, Executor};
use lccnn::graph::{schedule, CompiledGraph};
use lccnn::lcc::{decompose, LccConfig};
use lccnn::report::Table;
use lccnn::tensor::Matrix;
use lccnn::util::{stats, timer, Rng};

/// per-sample microseconds for a whole-batch closure
fn per_sample_us(batch: usize, warmup: usize, iters: usize, mut f: impl FnMut()) -> f64 {
    let samples = timer::bench(warmup, iters, &mut f);
    stats::mean(&samples) * 1e6 / batch as f64
}

fn main() {
    let mut rng = Rng::new(0);
    const BATCH: usize = 512;
    let mut t = Table::new(
        &format!("adder-graph execution, us/sample (batch {BATCH} for the engine columns)"),
        &["matrix", "algo", "adds", "depth", "max width", "interp", "scalar plan",
          "batch x1", "par scoped", "par pool", "pool speedup", "dense"],
    );
    for &(n, k) in &[(300usize, 30usize), (300, 60), (64, 9), (192, 3)] {
        let w = Matrix::randn(n, k, 0.5, &mut rng);
        let xs: Vec<Vec<f32>> = (0..BATCH).map(|_| rng.normal_vec(k, 1.0)).collect();
        let dense_us = per_sample_us(BATCH, 3, 30, || {
            for x in &xs {
                std::hint::black_box(w.matvec(std::hint::black_box(x)));
            }
        });
        for (name, cfg) in [("fp", LccConfig::fp()), ("fs", LccConfig::fs())] {
            let d = decompose(&w, &cfg);
            let g = d.graph();
            let s = schedule(g);

            let interp_us = per_sample_us(BATCH, 3, 30, || {
                for x in &xs {
                    std::hint::black_box(g.execute(std::hint::black_box(x)));
                }
            });

            // the seed CompiledGraph per-sample path (now an ExecPlan shim)
            let c = CompiledGraph::new(g);
            let mut scratch = Vec::new();
            let mut out = Vec::new();
            let scalar_us = per_sample_us(BATCH, 3, 30, || {
                for x in &xs {
                    c.execute_into(std::hint::black_box(x), &mut scratch, &mut out);
                    std::hint::black_box(&out);
                }
            });

            let serial = BatchEngine::with_config(g, ExecConfig::serial());
            let mut ys = Vec::new();
            let batch_us = per_sample_us(BATCH, 3, 30, || {
                serial.execute_batch_into(std::hint::black_box(&xs), &mut ys);
                std::hint::black_box(&ys);
            });

            let scoped = BatchEngine::with_config(
                g,
                ExecConfig {
                    chunk: 64,
                    parallel_min_batch: 128,
                    pool_mode: PoolMode::Scoped,
                    ..ExecConfig::default()
                },
            );
            let scoped_us = per_sample_us(BATCH, 3, 30, || {
                scoped.execute_batch_into(std::hint::black_box(&xs), &mut ys);
                std::hint::black_box(&ys);
            });

            let pooled = BatchEngine::with_config(
                g,
                ExecConfig {
                    chunk: 64,
                    parallel_min_batch: 128,
                    pool_mode: PoolMode::Persistent,
                    ..ExecConfig::default()
                },
            );
            let pooled_us = per_sample_us(BATCH, 3, 30, || {
                pooled.execute_batch_into(std::hint::black_box(&xs), &mut ys);
                std::hint::black_box(&ys);
            });

            t.add_row(vec![
                format!("{n}x{k}"),
                name.into(),
                g.additions().to_string(),
                s.depth.to_string(),
                s.max_width.to_string(),
                format!("{interp_us:.2}"),
                format!("{scalar_us:.2}"),
                format!("{batch_us:.2}"),
                format!("{scoped_us:.2}"),
                format!("{pooled_us:.2}"),
                format!("{:.2}x", scoped_us / pooled_us.max(1e-9)),
                format!("{dense_us:.2}"),
            ]);
        }
    }
    println!("{}", t.render());
    println!("interp = per-sample graph interpreter (oracle); scalar plan = seed");
    println!("CompiledGraph path; batch x1 = exec::BatchEngine lane-major, one");
    println!("thread; par scoped = chunks across per-call scoped threads; par");
    println!("pool = same chunks on the persistent worker pool (pool speedup =");
    println!("scoped/pool, the per-call spawn tax). depth = FPGA pipeline");
    println!("latency in adder stages; max width = peak simultaneous adders.");
    println!("The addition count, not wall time, is the hardware cost model —");
    println!("the engine columns measure the *simulation/serving* hot path.");
    println!("worker pool after run: {:?}", lccnn::exec::global_pool().stats());
}
