//! ABL-VM bench: shift-add VM throughput + ASAP schedule stats (the FPGA
//! parallelism proxy) on MLP-shaped decompositions.
//!
//!     cargo bench --bench adder_vm

use lccnn::graph::{schedule, CompiledGraph};
use lccnn::lcc::{decompose, LccConfig};
use lccnn::report::Table;
use lccnn::tensor::Matrix;
use lccnn::util::{stats, timer, Rng};

fn main() {
    let mut rng = Rng::new(0);
    let mut t = Table::new(
        "shift-add VM execution (per matvec) + schedule",
        &["matrix", "algo", "adds", "depth", "max width", "interp us", "compiled us",
          "speedup", "dense us"],
    );
    for &(n, k) in &[(300usize, 30usize), (300, 60), (64, 9), (192, 3)] {
        let w = Matrix::randn(n, k, 0.5, &mut rng);
        let x: Vec<f32> = rng.normal_vec(k, 1.0);
        let dense_samples = timer::bench(10, 200, || {
            std::hint::black_box(w.matvec(std::hint::black_box(&x)));
        });
        let dense_us = stats::mean(&dense_samples) * 1e6;
        for (name, cfg) in [("fp", LccConfig::fp()), ("fs", LccConfig::fs())] {
            let d = decompose(&w, &cfg);
            let g = d.graph();
            let s = schedule(g);
            let samples = timer::bench(10, 200, || {
                std::hint::black_box(g.execute(std::hint::black_box(&x)));
            });
            let us = stats::mean(&samples) * 1e6;
            let c = CompiledGraph::new(g);
            let mut scratch = Vec::new();
            let mut out = Vec::new();
            let csamples = timer::bench(10, 200, || {
                c.execute_into(std::hint::black_box(&x), &mut scratch, &mut out);
                std::hint::black_box(&out);
            });
            let cus = stats::mean(&csamples) * 1e6;
            t.add_row(vec![
                format!("{n}x{k}"),
                name.into(),
                g.additions().to_string(),
                s.depth.to_string(),
                s.max_width.to_string(),
                format!("{us:.1}"),
                format!("{cus:.1}"),
                format!("{:.1}x", us / cus.max(1e-9)),
                format!("{dense_us:.1}"),
            ]);
        }
    }
    println!("{}", t.render());
    println!("depth = FPGA pipeline latency in adder stages; max width = peak");
    println!("simultaneous adders. The VM is the numeric/count oracle, not a");
    println!("performance claim — the addition count is the hardware cost model.");
}
