//! RT bench: PJRT artifact execution overhead on the L3 hot path —
//! compile time (once), per-call latency of fwd/eval/train-step, and
//! host<->literal conversion cost.
//!
//!     cargo bench --bench runtime_pjrt

use lccnn::nn::mlp::MlpParams;
use lccnn::report::Table;
use lccnn::runtime::{HostTensor, Runtime};
use lccnn::util::{stats, timer, Rng};

fn main() {
    lccnn::util::logger::init();
    let rt = match Runtime::open_default() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP runtime_pjrt: {e:#}");
            return;
        }
    };
    let params = MlpParams::init(0);
    let mut rng = Rng::new(1);

    let mut t = Table::new(
        "PJRT runtime (CPU) — per-call latency",
        &["artifact", "compile ms", "call us (p50)", "call us (p99)"],
    );

    let host_params = || {
        vec![
            HostTensor::F32(vec![300, 784], params.w1.data().to_vec()),
            HostTensor::F32(vec![300], params.b1.clone()),
            HostTensor::F32(vec![10, 300], params.w2.data().to_vec()),
            HostTensor::F32(vec![10], params.b2.clone()),
        ]
    };

    // mlp_fwd
    let (exe, compile_secs) = timer::time(|| rt.get("mlp_fwd").unwrap());
    let x = rng.normal_vec(32 * 784, 1.0);
    let mut inputs = host_params();
    inputs.push(HostTensor::F32(vec![32, 784], x));
    let samples = timer::bench(5, 100, || {
        std::hint::black_box(exe.run(std::hint::black_box(&inputs)).unwrap());
    });
    let us: Vec<f64> = samples.iter().map(|s| s * 1e6).collect();
    t.add_row(vec![
        "mlp_fwd (batch 32)".into(),
        format!("{:.0}", compile_secs * 1e3),
        format!("{:.0}", stats::percentile(&us, 50.0)),
        format!("{:.0}", stats::percentile(&us, 99.0)),
    ]);

    // mlp_eval
    let (exe, compile_secs) = timer::time(|| rt.get("mlp_eval").unwrap());
    let x = rng.normal_vec(256 * 784, 1.0);
    let y: Vec<i32> = (0..256).map(|_| rng.below(10) as i32).collect();
    let mut inputs = host_params();
    inputs.push(HostTensor::F32(vec![256, 784], x));
    inputs.push(HostTensor::I32(vec![256], y));
    let samples = timer::bench(3, 50, || {
        std::hint::black_box(exe.run(std::hint::black_box(&inputs)).unwrap());
    });
    let us: Vec<f64> = samples.iter().map(|s| s * 1e6).collect();
    t.add_row(vec![
        "mlp_eval (batch 256)".into(),
        format!("{:.0}", compile_secs * 1e3),
        format!("{:.0}", stats::percentile(&us, 50.0)),
        format!("{:.0}", stats::percentile(&us, 99.0)),
    ]);

    // mlp_train_step
    let (exe, compile_secs) = timer::time(|| rt.get("mlp_train_step").unwrap());
    let zeros = |d: Vec<usize>| {
        let n: usize = d.iter().product();
        HostTensor::F32(d, vec![0.0; n])
    };
    let x = rng.normal_vec(128 * 784, 1.0);
    let y: Vec<i32> = (0..128).map(|_| rng.below(10) as i32).collect();
    let mut inputs = host_params();
    inputs.extend([
        zeros(vec![300, 784]),
        zeros(vec![300]),
        zeros(vec![10, 300]),
        zeros(vec![10]),
    ]);
    inputs.push(HostTensor::F32(vec![128, 784], x));
    inputs.push(HostTensor::I32(vec![128], y));
    inputs.push(HostTensor::scalar_f32(0.05));
    inputs.push(HostTensor::scalar_f32(0.0));
    inputs.push(HostTensor::F32(vec![784], vec![1.0; 784]));
    inputs.push(HostTensor::I32(vec![784], (0..784).collect()));
    inputs.push(HostTensor::scalar_f32(0.0));
    let samples = timer::bench(3, 50, || {
        std::hint::black_box(exe.run(std::hint::black_box(&inputs)).unwrap());
    });
    let us: Vec<f64> = samples.iter().map(|s| s * 1e6).collect();
    t.add_row(vec![
        "mlp_train_step (batch 128)".into(),
        format!("{:.0}", compile_secs * 1e3),
        format!("{:.0}", stats::percentile(&us, 50.0)),
        format!("{:.0}", stats::percentile(&us, 99.0)),
    ]);
    println!("{}", t.render());

    // host tensor -> literal conversion overhead (what the literal-
    // resident trainer state avoids — §Perf)
    let w1 = HostTensor::F32(vec![300, 784], params.w1.data().to_vec());
    let samples = timer::bench(10, 200, || {
        std::hint::black_box(w1.to_literal().unwrap());
    });
    println!(
        "literal conversion (300x784 f32): {:.0} us/op (the HostTensor path pays ~8 per step)",
        stats::mean(&samples) * 1e6
    );

    // end-to-end trainer step (literal-resident state) for comparison
    // with the raw HostTensor-path train-step row above
    let data = lccnn::data::synth_mnist::generate(512, 3);
    let mut tr = lccnn::train::MlpTrainer::new(&rt, &params).unwrap();
    let mut iter = lccnn::data::BatchIter::new(&data, tr.batch_size(), 4);
    let step_samples = timer::bench(3, 50, || {
        let (x, y, _) = iter.next_batch();
        std::hint::black_box(tr.step(&x, &y, 0.05).unwrap());
    });
    let us: Vec<f64> = step_samples.iter().map(|s| s * 1e6).collect();
    println!(
        "MlpTrainer.step (literal-resident state): p50 {:.0} us (vs HostTensor path above)",
        stats::percentile(&us, 50.0)
    );
}
