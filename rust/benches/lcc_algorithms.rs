//! ABL-LCC bench: FP vs FS vs CSD across matrix sizes and aspect ratios
//! (the Sec. III-A properties the paper states: LCC likes tall matrices;
//! FS wins on small/ill-behaved ones; FP parallelizes).
//!
//!     cargo bench --bench lcc_algorithms

use lccnn::graph::schedule;
use lccnn::lcc::{decompose, LccConfig};
use lccnn::quant::{matrix_csd_adders, FixedPointFormat};
use lccnn::report::Table;
use lccnn::tensor::Matrix;
use lccnn::util::{timer, Rng};

fn main() {
    let fmt = FixedPointFormat::default_weights();
    let mut rng = Rng::new(0);

    let mut t = Table::new(
        "LCC ablation: adders/entry and graph shape vs matrix size",
        &["N", "K", "csd/entry", "fp/entry", "fs/entry", "fp ratio", "fs ratio",
          "fp depth", "fs depth", "fp ms", "fs ms"],
    );
    for &n in &[32usize, 64, 128, 256, 512] {
        for &k in &[8usize, 16, 32] {
            let w = Matrix::randn(n, k, 0.5, &mut rng);
            let entries = (n * k) as f64;
            let csd = matrix_csd_adders(&w, fmt);
            let (dfp, fp_secs) = timer::time(|| decompose(&w, &LccConfig::fp()));
            let (dfs, fs_secs) = timer::time(|| decompose(&w, &LccConfig::fs()));
            let sfp = schedule(dfp.graph());
            let sfs = schedule(dfs.graph());
            t.add_row(vec![
                n.to_string(),
                k.to_string(),
                format!("{:.2}", csd as f64 / entries),
                format!("{:.2}", dfp.additions() as f64 / entries),
                format!("{:.2}", dfs.additions() as f64 / entries),
                format!("{:.1}", csd as f64 / dfp.additions().max(1) as f64),
                format!("{:.1}", csd as f64 / dfs.additions().max(1) as f64),
                sfp.depth.to_string(),
                sfs.depth.to_string(),
                format!("{:.0}", fp_secs * 1e3),
                format!("{:.0}", fs_secs * 1e3),
            ]);
        }
    }
    println!("{}", t.render());

    // slice-width ablation (DESIGN.md design-choice bench): auto width
    // (= log2 N) vs fixed widths
    let w = Matrix::randn(256, 32, 0.5, &mut rng);
    let csd = matrix_csd_adders(&w, fmt);
    let mut t2 = Table::new(
        "slice-width ablation (256x32, FS)",
        &["slice width", "additions", "ratio"],
    );
    for width in [2usize, 4, 8, 16, 32] {
        let mut cfg = LccConfig::fs();
        cfg.slice_width = Some(width);
        let d = decompose(&w, &cfg);
        t2.add_row(vec![
            width.to_string(),
            d.additions().to_string(),
            format!("{:.2}", csd as f64 / d.additions() as f64),
        ]);
    }
    let auto = decompose(&w, &LccConfig::fs());
    t2.add_row(vec![
        "auto (log2 N = 8)".into(),
        auto.additions().to_string(),
        format!("{:.2}", csd as f64 / auto.additions() as f64),
    ]);
    println!("{}", t2.render());

    // ill-behaved matrices: rank-deficient rows (paper footnote 1)
    let mut low = Matrix::randn(64, 16, 0.5, &mut rng);
    for r in 0..64 {
        // rows live in a 4-dim subspace
        let base = r % 4;
        let row: Vec<f32> = (0..16).map(|c| low.at(base, c) * (1.0 + r as f32 * 0.01)).collect();
        low.row_mut(r).copy_from_slice(&row);
    }
    let csd_low = matrix_csd_adders(&low, fmt);
    let fp_low = decompose(&low, &LccConfig::fp()).additions();
    let fs_low = decompose(&low, &LccConfig::fs()).additions();
    println!(
        "ill-behaved (rank-4) 64x16: csd {} | fp {} ({:.1}x) | fs {} ({:.1}x) — FS exploits the subspace",
        csd_low,
        fp_low,
        csd_low as f64 / fp_low.max(1) as f64,
        fs_low,
        csd_low as f64 / fs_low.max(1) as f64,
    );
}
