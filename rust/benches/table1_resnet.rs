//! TAB1 bench: regenerates the paper's Table I — compression/accuracy of
//! the residual CNN for {reg training, reg+LCC-FP, reg+LCC-FS} × {FK, PK}.
//!
//!     cargo bench --bench table1_resnet
//!
//! Environment knobs: LCCNN_BENCH_STEPS (default 200),
//! LCCNN_BENCH_EXAMPLES (default 2048). Paper reference (TinyImageNet
//! ResNet-34, baseline 59.0%): FS >> FP in ratio; FP adds only marginal
//! gain over reg-training; PK retains slightly more accuracy. The
//! absolute ratios here are on the scaled substrate (DESIGN.md).

use lccnn::config::ResnetPipelineConfig;
use lccnn::pipeline::run_resnet_pipeline;
use lccnn::report::{percent, Table};
use lccnn::runtime::Runtime;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    lccnn::util::logger::init();
    let rt = match Runtime::open_default() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP table1_resnet: artifacts unavailable: {e:#}");
            return;
        }
    };
    let cfg = ResnetPipelineConfig {
        train_steps: env_usize("LCCNN_BENCH_STEPS", 200),
        train_examples: env_usize("LCCNN_BENCH_EXAMPLES", 2048),
        ..Default::default()
    };
    match run_resnet_pipeline(&rt, &cfg) {
        Ok(out) => {
            let mut t = Table::new(
                &format!(
                    "Table I — residual CNN, baseline acc {} ({} additions)",
                    percent(out.baseline_accuracy),
                    out.baseline_additions
                ),
                &["method", "FK ratio", "FK acc", "PK ratio", "PK acc"],
            );
            for (name, fk, pk) in &out.rows {
                t.add_row(vec![
                    name.clone(),
                    format!("{:.1}", fk.ratio),
                    percent(fk.accuracy),
                    format!("{:.1}", pk.ratio),
                    percent(pk.accuracy),
                ]);
            }
            println!("{}", t.render());
            let fp = &out.rows[1];
            let fs = &out.rows[2];
            println!(
                "shape checks: FS-vs-FP ratio advantage (FK) = {:.2}x (paper: 46.5/25.2 = 1.8x); \
                 FS achieves >= 2x overall: {}",
                fs.1.ratio / fp.1.ratio.max(1e-9),
                fs.1.ratio >= 2.0 && fs.2.ratio >= 2.0
            );
        }
        Err(e) => eprintln!("table1 pipeline failed: {e:#}"),
    }
}
