//! FIG2 bench: regenerates the paper's Fig. 2 — compression-accuracy
//! tradeoff of the MLP's first layer across regularization strengths,
//! three series (regularized training only / + weight sharing / + LCC).
//!
//!     cargo bench --bench fig2_mlp
//!
//! Environment knobs: LCCNN_BENCH_STEPS (default 300),
//! LCCNN_BENCH_LAMBDAS (comma list, default "0.05,0.1,0.15,0.25,0.4").
//! Paper reference: dots < crosses < triangles in compression at roughly
//! constant accuracy; LCC multiplies the pruned+shared ratio by ~2.4-3.1x.

use lccnn::config::MlpPipelineConfig;
use lccnn::pipeline::run_mlp_pipeline;
use lccnn::report::{percent, Table};
use lccnn::runtime::Runtime;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_lambdas() -> Vec<f32> {
    std::env::var("LCCNN_BENCH_LAMBDAS")
        .ok()
        .map(|s| s.split(',').filter_map(|p| p.trim().parse().ok()).collect())
        .unwrap_or_else(|| vec![0.05, 0.1, 0.15, 0.25, 0.4])
}

fn main() {
    lccnn::util::logger::init();
    let rt = match Runtime::open_default() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP fig2_mlp: artifacts unavailable: {e:#}");
            return;
        }
    };
    let steps = env_usize("LCCNN_BENCH_STEPS", 300);
    let lambdas = env_lambdas();

    let mut table = Table::new(
        "Fig. 2 — MLP layer-1 compression-accuracy tradeoff (synthetic digits)",
        &["lambda", "series", "ratio", "top-1 acc", "cols", "clusters"],
    );
    let mut lcc_gain_min = f64::INFINITY;
    let mut lcc_gain_max: f64 = 0.0;

    for &lambda in &lambdas {
        let cfg = MlpPipelineConfig {
            train_steps: steps,
            share_retrain_steps: steps / 4,
            lambda,
            ..Default::default()
        };
        match run_mlp_pipeline(&rt, &cfg) {
            Ok(out) => {
                if lambda == lambdas[0] {
                    table.add_row(vec![
                        "-".into(),
                        "baseline (unregularized)".into(),
                        "1.0".into(),
                        percent(out.baseline_accuracy),
                        "784".into(),
                        "-".into(),
                    ]);
                }
                for s in &out.stages {
                    table.add_row(vec![
                        format!("{lambda}"),
                        s.stage.clone(),
                        format!("{:.1}", s.ratio),
                        percent(s.accuracy),
                        s.active_columns.to_string(),
                        if s.clusters > 0 { s.clusters.to_string() } else { "-".into() },
                    ]);
                }
                // the paper's combining-gain claim: LCC on top of
                // pruning+sharing multiplies the ratio further
                let gain = out.stages[2].ratio / out.stages[1].ratio.max(1e-9);
                lcc_gain_min = lcc_gain_min.min(gain);
                lcc_gain_max = lcc_gain_max.max(gain);
            }
            Err(e) => eprintln!("lambda {lambda}: pipeline failed: {e:#}"),
        }
    }
    println!("{}", table.render());
    println!(
        "LCC multiplier on top of pruning+sharing: {lcc_gain_min:.2}x .. {lcc_gain_max:.2}x \
         (paper Fig. 2: 2.4x .. 3.1x on MNIST)"
    );
}
