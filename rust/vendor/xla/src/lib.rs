//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The real crate links libxla/PJRT and is not available in this offline
//! build, so this stub keeps the crate compiling and cleanly degrades at
//! runtime:
//!
//! * [`Literal`] is a *working* host-side implementation (`vec1`,
//!   `reshape`, `to_vec`, `to_tuple`) — the `HostTensor` bridge and its
//!   tests run against it for real.
//! * [`PjRtClient::cpu`] always returns an error, so every PJRT-dependent
//!   path (`Runtime::open`, `PjrtService::start`, trainers) fails with a
//!   clear "PJRT unavailable" message instead of crashing. Integration
//!   tests already skip when the AOT artifacts are absent, which is
//!   always the case without a real PJRT runtime.
//!
//! Swapping in the real bindings is a Cargo.toml change only: point the
//! `xla` dependency at the actual xla-rs checkout; the API subset used by
//! the repo (`execute`, `to_literal_sync`, `to_tuple`, `HloModuleProto`,
//! `XlaComputation`) matches.

use std::borrow::Borrow;
use std::fmt;

/// Stub error: message-only, convertible into `anyhow::Error`.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT unavailable (offline `xla` stub crate; see rust/vendor/xla)"
    ))
}

#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host-side literal: dims + typed payload. Fully functional.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    payload: Payload,
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    fn wrap(v: Vec<Self>) -> Payload;
    fn unwrap(p: &Payload) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Payload {
        Payload::F32(v)
    }

    fn unwrap(p: &Payload) -> Option<Vec<Self>> {
        match p {
            Payload::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Payload {
        Payload::I32(v)
    }

    fn unwrap(p: &Payload) -> Option<Vec<Self>> {
        match p {
            Payload::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], payload: T::wrap(v.to_vec()) }
    }

    fn numel(&self) -> i64 {
        match &self.payload {
            Payload::F32(v) => v.len() as i64,
            Payload::I32(v) => v.len() as i64,
            Payload::Tuple(v) => v.len() as i64,
        }
    }

    /// Same payload under new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want != self.numel() {
            return Err(Error(format!(
                "reshape: {} elements into dims {dims:?}",
                self.numel()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), payload: self.payload.clone() })
    }

    /// Copy the payload out as a typed host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.payload)
            .ok_or_else(|| Error("literal payload has a different dtype".into()))
    }

    /// Unpack a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.payload {
            Payload::Tuple(v) => Ok(v),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Device buffer handle (never constructible through the stub).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("to_literal_sync"))
    }
}

/// Parsed HLO module (the stub does not parse; compilation fails first).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<Self> {
        Err(unavailable(&format!("parse HLO text {path}")))
    }
}

/// Computation wrapper.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// Compiled executable (never constructible through the stub).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute"))
    }
}

/// PJRT client handle.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(lit.dims(), &[4]);
        let r = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(lit.reshape(&[3]).is_err());
    }

    #[test]
    fn client_is_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must refuse");
        assert!(err.to_string().contains("PJRT unavailable"));
    }
}
