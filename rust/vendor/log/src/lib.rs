//! Minimal offline stand-in for the [`log`](https://crates.io/crates/log)
//! facade: `Level`/`LevelFilter`, `Record`/`Metadata`, the `Log` trait,
//! `set_logger`/`set_max_level`, and the five level macros. Vendored as a
//! path dependency because the build has no crates.io access; the API
//! mirrors the subset `crate::util::logger` and the pipeline use.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Severity of a log record (most severe first, like the real crate).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

/// Global verbosity cap set via [`set_max_level`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

/// Metadata about a record: level + target (module path).
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record handed to the installed logger.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging backend. Install one with [`set_logger`].
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

/// Returned when [`set_logger`] is called a second time.
#[derive(Debug)]
pub struct SetLoggerError(());

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Install the global logger (first call wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global verbosity cap checked by the level macros.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::SeqCst);
}

/// Current verbosity cap.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

#[doc(hidden)]
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if (level as usize) > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let record = Record { metadata: Metadata { level, target }, args };
        if logger.enabled(record.metadata()) {
            logger.log(&record);
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_log($lvl, ::std::module_path!(), ::std::format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static HITS: AtomicUsize = AtomicUsize::new(0);

    struct Counter;

    impl Log for Counter {
        fn enabled(&self, _metadata: &Metadata) -> bool {
            true
        }

        fn log(&self, record: &Record) {
            assert_eq!(record.level(), Level::Info);
            assert!(record.target().contains("log"));
            let _ = format!("{}", record.args());
            HITS.fetch_add(1, Ordering::SeqCst);
        }

        fn flush(&self) {}
    }

    #[test]
    fn macros_respect_max_level() {
        static C: Counter = Counter;
        let _ = set_logger(&C);
        set_max_level(LevelFilter::Info);
        info!("hello {}", 1);
        let after_info = HITS.load(Ordering::SeqCst);
        assert!(after_info >= 1);
        debug!("filtered {}", 2); // above the cap: not delivered
        assert_eq!(HITS.load(Ordering::SeqCst), after_info);
        assert_eq!(max_level(), LevelFilter::Info);
    }
}
