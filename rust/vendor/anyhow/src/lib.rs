//! Minimal offline stand-in for the [`anyhow`](https://crates.io/crates/anyhow)
//! crate, carrying exactly the API surface this repository uses: the
//! string-backed [`Error`] type, the `Result` alias, the [`Context`]
//! extension trait for `Result`/`Option`, and the `anyhow!` / `bail!` /
//! `ensure!` macros.
//!
//! The build is fully offline (no crates.io access), so instead of the
//! real crate we vendor this shim as a path dependency. Error *chains*
//! are flattened into one message (`"context: cause"`), which is what the
//! callers render anyway (`{e}` / `{e:#}` / `{e:?}`).

use std::error::Error as StdError;
use std::fmt;

/// String-backed error. Unlike `std` errors it intentionally does *not*
/// implement `std::error::Error`, which is what makes the blanket
/// `From<E: std::error::Error>` conversion below coherent (the same
/// trick the real anyhow uses).
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string() }
    }

    fn wrap<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — a `std::result::Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error variant of a `Result` or to a `None`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"))
    }

    #[test]
    fn context_chains_into_message() {
        let e = io_err().context("reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest: disk on fire");
        let e = io_err().with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 3: disk on fire");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
        assert_eq!(Some(1u32).context("missing").unwrap(), 1);
    }

    #[test]
    fn macros_format() {
        fn f(flag: bool) -> Result<u32> {
            ensure!(!flag, "flag was {}", flag);
            if flag {
                bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(f(false).unwrap(), 7);
        assert_eq!(f(true).unwrap_err().to_string(), "flag was true");
        assert_eq!(anyhow!("x = {}", 2).to_string(), "x = 2");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("41").unwrap(), 41);
        assert!(parse("nope").is_err());
    }
}
