//! End-to-end driver (DESIGN.md: the full-system validation example),
//! reworked through the full-network compression path:
//!
//!     cargo run --release --example mlp_mnist_pipeline
//!
//! Proves the network subsystem composes on a real small workload:
//!   1. generates a synthetic-MNIST dataset,
//!   2. trains the LeNet-300-100-shaped MLP (784-300-100-10) with plain
//!      in-process SGD — no AOT artifacts required,
//!   3. converts it into a multi-layer `NetworkCheckpoint` and compresses
//!      every layer through ONE per-layer recipe (prune + LCC globally,
//!      an LCC-only override for the tiny output layer),
//!   4. self-checks the chained batch-major `NetworkExecutor` bit-exact
//!      against the hand-chained `NaiveExecutor` oracle, and
//!   5. evaluates compressed accuracy through the shift-add engine and
//!      applies the recipe's accuracy gate vs the dense baseline.
//!
//! Runs in well under a minute on one CPU core.
//! Flags: --steps N --train N --test N --seed S --epsilon F.

use anyhow::Result;
use lccnn::compress::{LccSpec, NetworkPipeline, PruneSpec, Recipe, StageSpec};
use lccnn::data::synth_mnist;
use lccnn::exec::Executor;
use lccnn::nn::mlp3::argmax;
use lccnn::nn::Mlp3;

fn main() -> Result<()> {
    lccnn::util::logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut steps = 400usize;
    let mut train_n = 2000usize;
    let mut test_n = 500usize;
    let mut seed = 0u64;
    let mut epsilon = 0.05f64;
    let mut i = 0;
    while i + 1 < args.len() {
        match args[i].as_str() {
            "--steps" => steps = args[i + 1].parse()?,
            "--train" => train_n = args[i + 1].parse()?,
            "--test" => test_n = args[i + 1].parse()?,
            "--seed" => seed = args[i + 1].parse()?,
            "--epsilon" => epsilon = args[i + 1].parse()?,
            other => anyhow::bail!("unknown flag {other}"),
        }
        i += 2;
    }

    let (train, test) = synth_mnist::generate(train_n + test_n, seed).split_off(test_n);
    let mut mlp = Mlp3::lenet_300_100(seed + 1);
    println!(
        "training MLP 784-300-100-10 for {steps} SGD steps (batch 32) on {} examples",
        train.len()
    );
    let mut done = 0usize;
    while done < steps {
        let n = 100.min(steps - done);
        mlp.train_sgd(&train, n, 32, 0.1, seed + 2 + done as u64);
        done += n;
        println!("  step {done:>4}  test acc {:.1} %", 100.0 * mlp.accuracy(&test));
    }
    let dense = mlp.accuracy(&test);
    println!("dense baseline: {:.1} % top-1 on {} held-out examples\n", 100.0 * dense, test.len());

    // one recipe for the whole network: prune + LCC globally, with a
    // per-layer override pinning the tiny 10x100 output layer to
    // LCC-only (pruning whole input features of the classifier head
    // buys little; weight sharing is skipped throughout because
    // clustering *trained* columns collapses learned features)
    let mut recipe = Recipe {
        stages: vec![StageSpec::Prune(PruneSpec::default()), StageSpec::Lcc(LccSpec::default())],
        gate_epsilon: Some(epsilon),
        ..Recipe::default()
    };
    recipe.layers.entry(3).or_default().stages = Some(vec!["lcc".to_string()]);

    let ckpt = mlp.to_network_checkpoint()?;
    let net = NetworkPipeline::from_recipe(&recipe)?.run(&ckpt)?;
    println!("{}", net.report().render());

    // self-check: the chained batch-major engine must reproduce the
    // hand-chained NaiveExecutor oracle bit for bit (float mode)
    let exec = net.executor()?;
    let n_check = 64.min(test.len());
    let sample: Vec<Vec<f32>> = (0..n_check).map(|i| test.example(i).to_vec()).collect();
    let got = exec.execute_batch(&sample);
    let want = net.oracle_forward_batch(&sample);
    anyhow::ensure!(got == want, "network engine diverged from the hand-chained oracle");
    println!("oracle self-check: {} requests bit-identical to the chained oracle", sample.len());

    let mut correct = 0usize;
    for i in 0..test.len() {
        if argmax(&exec.execute_one(test.example(i))) == test.labels[i] as usize {
            correct += 1;
        }
    }
    let acc = correct as f64 / test.len() as f64;
    println!(
        "compressed accuracy through the shift-add engine: {:.1} % ({:.1}x fewer additions)",
        100.0 * acc,
        net.report().total_ratio()
    );
    anyhow::ensure!(
        acc + 1e-12 >= dense - epsilon,
        "accuracy gate failed: {acc:.3} vs dense {dense:.3} - {epsilon}"
    );
    println!("accuracy gate passed: within {epsilon} of the dense baseline");
    Ok(())
}
