//! End-to-end driver (DESIGN.md: the full-system validation example).
//!
//!     cargo run --release --example mlp_mnist_pipeline
//!
//! Proves all three layers compose on a real small workload:
//!   1. rust generates a synthetic-MNIST dataset,
//!   2. trains the 784-300-10 MLP through the AOT-compiled JAX train-step
//!      artifact (PJRT CPU; the prox is the Pallas kernel), logging the
//!      loss curve,
//!   3. prunes, clusters (affinity propagation), retrains with weight
//!      sharing, decomposes with LCC,
//!   4. evaluates the compressed model through the shift-add VM, and
//!   5. prints the Fig.2-style stage table + the loss curves.
//!
//! Runs in a few minutes on one CPU core. Flags: --steps N --lambda F.

use anyhow::Result;
use lccnn::config::MlpPipelineConfig;
use lccnn::pipeline::run_mlp_pipeline;
use lccnn::report::{percent, ratio, Table};
use lccnn::runtime::Runtime;

fn main() -> Result<()> {
    lccnn::util::logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = MlpPipelineConfig {
        train_steps: 400,
        share_retrain_steps: 100,
        lambda: 0.2,
        ..Default::default()
    };
    let mut i = 0;
    while i + 1 < args.len() {
        match args[i].as_str() {
            "--steps" => cfg.train_steps = args[i + 1].parse()?,
            "--lambda" => cfg.lambda = args[i + 1].parse()?,
            "--seed" => cfg.seed = args[i + 1].parse()?,
            other => anyhow::bail!("unknown flag {other}"),
        }
        i += 2;
    }

    let rt = Runtime::open_default()?;
    println!("platform: {} | artifacts: {}", rt.platform(), rt.artifact_names().len());
    println!(
        "training MLP 784-300-10 for {} steps (batch 128) + {} sharing-retrain steps; lambda = {}",
        cfg.train_steps, cfg.share_retrain_steps, cfg.lambda
    );

    let out = run_mlp_pipeline(&rt, &cfg)?;

    println!("\nbaseline loss curve (unregularized):");
    for (step, loss) in &out.baseline_curve {
        println!("  step {step:>4}  loss {loss:.4}");
    }
    println!("\nregularized loss curve (lambda = {}):", cfg.lambda);
    for (step, loss) in &out.reg_curve {
        println!("  step {step:>4}  loss {loss:.4}");
    }

    let mut t = Table::new(
        "compression pipeline (layer-1 additions, Fig. 2 axes)",
        &["stage", "additions", "ratio", "top-1 acc", "active cols", "clusters"],
    );
    t.add_row(vec![
        "baseline (dense, CSD)".into(),
        out.baseline_additions.to_string(),
        "1.0".into(),
        percent(out.baseline_accuracy),
        "784".into(),
        "-".into(),
    ]);
    for s in &out.stages {
        t.add_row(vec![
            s.stage.clone(),
            s.additions.to_string(),
            ratio(out.baseline_additions, s.additions),
            percent(s.accuracy),
            s.active_columns.to_string(),
            if s.clusters > 0 { s.clusters.to_string() } else { "-".into() },
        ]);
    }
    println!("\n{}", t.render());
    println!("LCC graph verification SQNR: {:.1} dB", out.lcc_sqnr_db);
    println!("(compressed accuracy is evaluated through the shift-add VM — the");
    println!(" same adder graph an FPGA would instantiate.)");
    Ok(())
}
