//! Multi-model serving over the registry: several compressed models —
//! distinct shapes, per-model engine tuning — resident in one process,
//! served by one router with fair per-model batching, with hot add and
//! hot remove exercised under load.
//!
//!     cargo run --release --example serve_multi_model
//!
//! Three models are LCC decompositions of random weight matrices (no
//! training needed for the demo); a fourth arrives the deployment way —
//! a checkpoint directory with a compression `recipe.toml`, loaded at
//! runtime through `ModelRegistry::load_checkpoint_with_recipe`, so the
//! served engine is pruned+shared+LCC'd per the recipe. Every response
//! is checked bit-exact against the `NaiveExecutor` oracle for that
//! model's graph, so the example doubles as an end-to-end correctness
//! run.

use anyhow::{bail, Result};
use lccnn::compress::{demo_weights, Pipeline, Recipe};
use lccnn::config::{ExecConfig, ServeConfig};
use lccnn::exec::{Executor, NaiveExecutor};
use lccnn::lcc::{decompose, LccConfig};
use lccnn::nn::npy::NpyArray;
use lccnn::nn::ParamStore;
use lccnn::serve::{ModelRegistry, Server};
use lccnn::tensor::Matrix;
use lccnn::util::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Build one demo model: LCC-decompose a random rows x cols matrix and
/// return its name, graph and oracle.
fn demo_model(name: &str, rows: usize, cols: usize, seed: u64) -> (String, NaiveExecutor) {
    let mut rng = Rng::new(seed);
    let w = Matrix::randn(rows, cols, 0.5, &mut rng);
    let d = decompose(&w, &LccConfig::fs());
    println!("model {name:?}: {rows}x{cols} weight -> {} adds", d.additions());
    (name.to_string(), NaiveExecutor::new(d.graph().clone()))
}

fn main() -> Result<()> {
    lccnn::util::logger::init();
    let registry = Arc::new(ModelRegistry::new());

    // three resident models with different shapes and tunings
    let mut oracles = Vec::new();
    for (name, rows, cols, seed, exec) in [
        ("mlp-s", 48usize, 12usize, 1u64, ExecConfig::serial()),
        ("mlp-m", 96, 20, 2, ExecConfig::default()),
        ("mlp-l", 160, 28, 3, ExecConfig { chunk: 32, ..ExecConfig::default() }),
    ] {
        let (name, oracle) = demo_model(name, rows, cols, seed);
        registry.register_graph(&name, oracle.graph(), exec, 32);
        oracles.push((name, oracle));
    }

    // the fourth model arrives as an artifact directory: checkpoint +
    // recipe, loaded through the registry's recipe path (the engine is
    // pruned+shared+LCC'd, not LCC-only)
    let artifact_dir =
        std::env::temp_dir().join(format!("lccnn-smm-artifact-{}", std::process::id()));
    let recipe_w = demo_weights(64, 5, 4, 77);
    let recipe = Recipe { exec: ExecConfig::serial(), ..Recipe::default() };
    {
        let mut store = ParamStore::new();
        store.insert(
            "weight",
            NpyArray::f32(vec![recipe_w.rows(), recipe_w.cols()], recipe_w.data().to_vec()),
        );
        store.save(&artifact_dir)?;
        recipe.save(&artifact_dir.join("recipe.toml"))?;
    }
    let entry = registry.load_checkpoint_with_recipe("recipe-mlp", &artifact_dir, None, 32)?;
    println!(
        "model \"recipe-mlp\": loaded via recipe.toml ({:?} inputs, pruned+shared+LCC)",
        entry.input_dim()
    );
    // its oracle: the same recipe run directly, composed with the
    // NaiveExecutor over the lowered graph
    let recipe_model = Pipeline::from_recipe(&recipe)?.run(&recipe_w)?;
    let recipe_oracle =
        NaiveExecutor::new(recipe_model.lcc().expect("recipe ends in lcc").graph().clone());

    let cfg = ServeConfig { max_batch: 16, batch_timeout_us: 200, ..Default::default() };
    let server = Server::start_registry(Arc::clone(&registry), cfg);

    // 4 client threads hammer all models round-robin; main thread hot
    // adds a fourth model and hot removes it again mid-load
    let n_clients = 4;
    let per_client = 400;
    let mismatches = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for t in 0..n_clients {
            let server = &server;
            let oracles = &oracles;
            let mismatches = &mismatches;
            scope.spawn(move || {
                let mut rng = Rng::new(100 + t as u64);
                for k in 0..per_client {
                    let (name, oracle) = &oracles[(t + k) % oracles.len()];
                    let x = rng.normal_vec(oracle.num_inputs(), 1.0);
                    let want = oracle.execute_one(&x);
                    match server.infer_model(name, x) {
                        Ok(y) if y == want => {}
                        Ok(y) => {
                            eprintln!("{name:?}: engine {y:?} != oracle {want:?}");
                            mismatches.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e) => {
                            eprintln!("{name:?}: {e}");
                            mismatches.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }

        // hammer the recipe-loaded model from the main thread while the
        // clients run: gather kept -> segment sums -> oracle must match
        // the served response bit-exactly
        let slcc = recipe_model.lcc().expect("lcc");
        let mut rng = Rng::new(400);
        for _ in 0..100 {
            let x = rng.normal_vec(recipe_w.cols(), 1.0);
            let xk: Vec<f32> = recipe_model.kept().iter().map(|&i| x[i]).collect();
            let want = recipe_oracle.execute_one(&slcc.layer.segment_sums(&xk));
            match server.infer_model("recipe-mlp", x) {
                Ok(y) if y == want => {}
                Ok(y) => {
                    eprintln!("\"recipe-mlp\": engine {y:?} != oracle {want:?}");
                    mismatches.fetch_add(1, Ordering::Relaxed);
                }
                Err(e) => {
                    eprintln!("\"recipe-mlp\": {e}");
                    mismatches.fetch_add(1, Ordering::Relaxed);
                }
            }
        }

        // hot add + hot remove while the clients are running
        let (name, oracle) = demo_model("hotswap", 64, 16, 9);
        registry.register_graph(&name, oracle.graph(), ExecConfig::default(), 32);
        let mut rng = Rng::new(500);
        for _ in 0..50 {
            let x = rng.normal_vec(oracle.num_inputs(), 1.0);
            let want = oracle.execute_one(&x);
            match server.infer_model(&name, x) {
                Ok(y) if y == want => {}
                _ => {
                    mismatches.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        registry.remove(&name);
        // removed: new submits are cleanly rejected
        if server.infer_model(&name, vec![0.0; 16]).is_ok() {
            mismatches.fetch_add(1, Ordering::Relaxed);
        }
    });

    println!("\nper-model stats:");
    for name in oracles.iter().map(|(n, _)| n.as_str()).chain(["recipe-mlp"]) {
        let s = server.model_stats(name);
        println!(
            "  {name:<10} {:>6} req  {:>5} batches  mean batch {:>5.1}  p50 {:>8.1} us  p99 {:>8.1} us",
            s.requests, s.batches, s.mean_batch_size, s.p50_latency_us, s.p99_latency_us
        );
    }
    println!("\n{}", server.metrics_text());
    let stats = server.shutdown();
    std::fs::remove_dir_all(&artifact_dir).ok();
    let bad = mismatches.load(Ordering::Relaxed);
    if bad > 0 {
        bail!("{bad} responses were wrong or failed");
    }
    println!(
        "served {} requests across {} models; every response bit-identical to the oracle",
        stats.requests,
        oracles.len() + 2
    );
    Ok(())
}
