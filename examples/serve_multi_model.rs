//! Multi-model serving over the registry: several compressed models —
//! distinct shapes, per-model engine tuning — resident in one process,
//! served by one router with fair per-model batching, with hot add and
//! hot remove exercised under load.
//!
//!     cargo run --release --example serve_multi_model
//!
//! Each model is an LCC decomposition of a random weight matrix (no
//! training needed for the demo). Every response is checked bit-exact
//! against the `NaiveExecutor` oracle for that model's graph, so the
//! example doubles as an end-to-end correctness run.

use anyhow::{bail, Result};
use lccnn::config::{ExecConfig, ServeConfig};
use lccnn::exec::{Executor, NaiveExecutor};
use lccnn::lcc::{decompose, LccConfig};
use lccnn::serve::{ModelRegistry, Server};
use lccnn::tensor::Matrix;
use lccnn::util::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Build one demo model: LCC-decompose a random rows x cols matrix and
/// return its name, graph and oracle.
fn demo_model(name: &str, rows: usize, cols: usize, seed: u64) -> (String, NaiveExecutor) {
    let mut rng = Rng::new(seed);
    let w = Matrix::randn(rows, cols, 0.5, &mut rng);
    let d = decompose(&w, &LccConfig::fs());
    println!("model {name:?}: {rows}x{cols} weight -> {} adds", d.additions());
    (name.to_string(), NaiveExecutor::new(d.graph().clone()))
}

fn main() -> Result<()> {
    lccnn::util::logger::init();
    let registry = Arc::new(ModelRegistry::new());

    // three resident models with different shapes and tunings
    let mut oracles = Vec::new();
    for (name, rows, cols, seed, exec) in [
        ("mlp-s", 48usize, 12usize, 1u64, ExecConfig::serial()),
        ("mlp-m", 96, 20, 2, ExecConfig::default()),
        ("mlp-l", 160, 28, 3, ExecConfig { chunk: 32, ..ExecConfig::default() }),
    ] {
        let (name, oracle) = demo_model(name, rows, cols, seed);
        registry.register_graph(&name, oracle.graph(), exec, 32);
        oracles.push((name, oracle));
    }

    let cfg = ServeConfig { max_batch: 16, batch_timeout_us: 200, ..Default::default() };
    let server = Server::start_registry(Arc::clone(&registry), cfg);

    // 4 client threads hammer all models round-robin; main thread hot
    // adds a fourth model and hot removes it again mid-load
    let n_clients = 4;
    let per_client = 400;
    let mismatches = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for t in 0..n_clients {
            let server = &server;
            let oracles = &oracles;
            let mismatches = &mismatches;
            scope.spawn(move || {
                let mut rng = Rng::new(100 + t as u64);
                for k in 0..per_client {
                    let (name, oracle) = &oracles[(t + k) % oracles.len()];
                    let x = rng.normal_vec(oracle.num_inputs(), 1.0);
                    let want = oracle.execute_one(&x);
                    match server.infer_model(name, x) {
                        Ok(y) if y == want => {}
                        Ok(y) => {
                            eprintln!("{name:?}: engine {y:?} != oracle {want:?}");
                            mismatches.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e) => {
                            eprintln!("{name:?}: {e}");
                            mismatches.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }

        // hot add + hot remove while the clients are running
        let (name, oracle) = demo_model("hotswap", 64, 16, 9);
        registry.register_graph(&name, oracle.graph(), ExecConfig::default(), 32);
        let mut rng = Rng::new(500);
        for _ in 0..50 {
            let x = rng.normal_vec(oracle.num_inputs(), 1.0);
            let want = oracle.execute_one(&x);
            match server.infer_model(&name, x) {
                Ok(y) if y == want => {}
                _ => {
                    mismatches.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        registry.remove(&name);
        // removed: new submits are cleanly rejected
        if server.infer_model(&name, vec![0.0; 16]).is_ok() {
            mismatches.fetch_add(1, Ordering::Relaxed);
        }
    });

    println!("\nper-model stats:");
    for (name, _) in &oracles {
        let s = server.model_stats(name);
        println!(
            "  {name:<8} {:>6} req  {:>5} batches  mean batch {:>5.1}  p50 {:>8.1} us  p99 {:>8.1} us",
            s.requests, s.batches, s.mean_batch_size, s.p50_latency_us, s.p99_latency_us
        );
    }
    println!("\n{}", server.metrics_text());
    let stats = server.shutdown();
    let bad = mismatches.load(Ordering::Relaxed);
    if bad > 0 {
        bail!("{bad} responses were wrong or failed");
    }
    println!(
        "served {} requests across {} models; every response bit-identical to the oracle",
        stats.requests,
        oracles.len() + 1
    );
    Ok(())
}
