//! Sharded execution, end to end and self-checking:
//!
//!     cargo run --release --example sharded_exec
//!
//! 1. A raw LCC adder graph is partitioned by output-column ranges into
//!    shard engines (`exec::ShardedExecutor`) and every batch is checked
//!    bit-exact against both the unsharded `BatchEngine` and the
//!    `NaiveExecutor` oracle, across shard counts, shard modes and
//!    uneven splits.
//! 2. A compression recipe carrying `[compress.shard]` is run through
//!    `compress::Pipeline`, written out as an artifact directory,
//!    reloaded through `serve::ModelRegistry` (recipe discovery), and
//!    served — the served shards must be bit-identical to the unsharded
//!    serve of the same weights.
//!
//! Exits nonzero on any mismatch.

use anyhow::{bail, Result};
use lccnn::compress::{demo_weights, Pipeline, Recipe};
use lccnn::config::{ExecConfig, ShardMode, ShardSpec};
use lccnn::exec::{BatchEngine, ExecPlan, Executor, NaiveExecutor, ShardPlan, ShardedExecutor};
use lccnn::lcc::{decompose, LccConfig};
use lccnn::nn::npy::NpyArray;
use lccnn::nn::ParamStore;
use lccnn::serve::ModelRegistry;
use lccnn::tensor::Matrix;
use lccnn::util::Rng;

fn main() -> Result<()> {
    lccnn::util::logger::init();
    let mut mismatches = 0usize;

    // --- 1. raw graph: sharded engines vs unsharded vs oracle ---------
    let mut rng = Rng::new(1);
    let w = Matrix::randn(96, 20, 0.5, &mut rng);
    let d = decompose(&w, &LccConfig::fs());
    let g = d.graph();
    let plan = ExecPlan::new(g);
    let oracle = NaiveExecutor::new(g.clone());
    let unsharded = BatchEngine::with_config(g, ExecConfig::default());
    println!(
        "graph: {}x{} weight -> {} adds, {} outputs",
        w.rows(),
        w.cols(),
        g.additions(),
        g.num_outputs()
    );
    for shards in [2usize, 3, 5] {
        let sp = ShardPlan::even(&plan, shards);
        println!(
            "  x{shards}: ranges {:?}, {} adds total ({:.2}x replication)",
            sp.ranges(),
            sp.total_additions(),
            sp.total_additions() as f64 / plan.additions().max(1) as f64
        );
        for mode in [ShardMode::Serial, ShardMode::Parallel] {
            let engine = ShardedExecutor::from_graph(
                g,
                ExecConfig { shards, shard_mode: mode, ..ExecConfig::default() },
            );
            for b in [1usize, 7, 64] {
                let xs: Vec<Vec<f32>> =
                    (0..b).map(|_| rng.normal_vec(g.num_inputs(), 1.0)).collect();
                let want = oracle.execute_batch(&xs);
                if unsharded.execute_batch(&xs) != want {
                    eprintln!("unsharded engine diverged from the oracle (b {b})");
                    mismatches += 1;
                }
                if engine.execute_batch(&xs) != want {
                    eprintln!("sharded x{shards} {mode:?} diverged (b {b})");
                    mismatches += 1;
                }
            }
        }
    }
    // uneven split through explicit cuts
    let n_out = g.num_outputs();
    let sp = ShardPlan::with_cuts(&plan, &[1, n_out / 2])?;
    let uneven = ShardedExecutor::from_shard_plan(sp, ExecConfig::default());
    let xs: Vec<Vec<f32>> = (0..13).map(|_| rng.normal_vec(g.num_inputs(), 1.0)).collect();
    if uneven.execute_batch(&xs) != oracle.execute_batch(&xs) {
        eprintln!("uneven-cut sharding diverged");
        mismatches += 1;
    }
    println!("raw-graph sweep done: shard engines match oracle + unsharded engine");

    // --- 2. recipe artifact: [compress.shard] served through registry -
    let weights = demo_weights(48, 4, 4, 7);
    let plain = Recipe { exec: ExecConfig::serial(), ..Recipe::default() };
    let sharded_recipe = Recipe {
        shard: Some(ShardSpec { shards: 3, mode: ShardMode::Parallel }),
        ..plain.clone()
    };
    let artifact_dir =
        std::env::temp_dir().join(format!("lccnn-sharded-exec-{}", std::process::id()));
    let mut store = ParamStore::new();
    store.insert(
        "weight",
        NpyArray::f32(vec![weights.rows(), weights.cols()], weights.data().to_vec()),
    );
    store.save(&artifact_dir)?;
    sharded_recipe.save(&artifact_dir.join("recipe.toml"))?;

    let registry = ModelRegistry::new();
    let entry = registry.load_checkpoint_with_recipe("sharded", &artifact_dir, None, 16)?;
    let reference = Pipeline::from_recipe(&plain)?.run(&weights)?.into_executor();
    println!(
        "artifact reloaded via recipe.toml: {:?} inputs, shards in recipe: {}",
        entry.input_dim(),
        sharded_recipe.shard_spec().map(|s| s.shards).unwrap_or(1)
    );
    let mut rng = Rng::new(9);
    for b in [1usize, 6, 20] {
        let xs: Vec<Vec<f32>> = (0..b).map(|_| rng.normal_vec(weights.cols(), 1.0)).collect();
        let want = reference.execute_batch(&xs);
        match entry.eval_batch(&xs) {
            Ok(got) if got == want => {}
            Ok(_) => {
                eprintln!("served shards diverged from the unsharded artifact (b {b})");
                mismatches += 1;
            }
            Err(e) => {
                eprintln!("serving the sharded artifact failed: {e}");
                mismatches += 1;
            }
        }
    }
    std::fs::remove_dir_all(&artifact_dir).ok();

    if mismatches > 0 {
        bail!("{mismatches} mismatches");
    }
    println!("sharded execution verified: scatter/gather is bit-identical end to end");
    Ok(())
}
