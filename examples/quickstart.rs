//! Quickstart: the paper's worked example (eq. 2) and a first LCC
//! decomposition.
//!
//!     cargo run --release --example quickstart
//!
//! Walks through: CSD cost of a small constant matrix, an LCC
//! decomposition of the same matrix, numeric verification on the
//! shift-add VM, the CSD-vs-LCC comparison on a realistic tall matrix,
//! and batch-major execution through the `exec` engine.

use lccnn::exec::{BatchEngine, Executor, NaiveExecutor};
use lccnn::graph::{schedule, verify_against};
use lccnn::lcc::{decompose, LccConfig};
use lccnn::quant::{matrix_csd_adders, FixedPointFormat};
use lccnn::report::{ratio, Table};
use lccnn::tensor::Matrix;
use lccnn::util::{timer, Rng};

fn main() {
    // --- the paper's eq. (2) matrix -------------------------------------
    let w = Matrix::from_rows(&[&[2.0, 0.375], &[3.75, 1.0]]);
    let fmt = FixedPointFormat::new(3, 8);
    let csd = matrix_csd_adders(&w, fmt);
    println!("eq. (2) matrix W = [[2, 0.375], [3.75, 1]]");
    println!("CSD baseline: {csd} additions (the paper counts 4: 2 adds + 2 subs)");

    // LCC finds the shared subexpression m(x1,x2) the paper points out:
    let d = decompose(&w, &LccConfig::fs());
    println!("LCC (FS): {} additions, SQNR {:.1} dB", d.additions(), d.sqnr_db(&w));
    let y = d.apply(&[1.0, 1.0]);
    println!("W [1, 1] via shift-add VM = [{:.4}, {:.4}] (exact: [2.375, 4.75])", y[0], y[1]);

    // --- a realistic tall matrix ----------------------------------------
    let mut rng = Rng::new(0);
    let tall = Matrix::randn(256, 16, 0.5, &mut rng);
    let base = matrix_csd_adders(&tall, FixedPointFormat::default_weights());

    let mut table = Table::new(
        "random 256x16 weight matrix",
        &["method", "additions", "ratio", "sqnr dB", "depth", "max width"],
    );
    table.add_row(vec![
        "CSD (baseline)".into(),
        base.to_string(),
        "1.0".into(),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    for (name, cfg) in [("LCC FP", LccConfig::fp()), ("LCC FS", LccConfig::fs())] {
        let d = decompose(&tall, &cfg);
        let rep = verify_against(d.graph(), &tall, 8, &mut rng);
        assert!(rep.sqnr_db > 25.0, "verification failed: {rep:?}");
        let s = schedule(d.graph());
        table.add_row(vec![
            name.into(),
            d.additions().to_string(),
            ratio(base, d.additions()),
            format!("{:.1}", rep.sqnr_db),
            s.depth.to_string(),
            s.max_width.to_string(),
        ]);
    }
    println!("\n{}", table.render());
    println!("note: FP graphs are shallow/wide (parallel-friendly), FS graphs");
    println!("deep/narrow but cheaper — the paper's Sec. III-A tradeoff.");

    // --- batch-major execution through the unified engine ---------------
    // Everything above executed one sample at a time. Serving and
    // accuracy evaluation run the same graphs through exec::BatchEngine:
    // lane-major kernels, pooled buffers, parallel chunks.
    let d = decompose(&tall, &LccConfig::fs());
    let engine = BatchEngine::new(d.graph());
    let oracle = NaiveExecutor::new(d.graph().clone());
    let batch: Vec<Vec<f32>> = (0..512).map(|_| rng.normal_vec(16, 1.0)).collect();
    let (ys_engine, engine_secs) = timer::time(|| engine.execute_batch(&batch));
    let (ys_oracle, oracle_secs) = timer::time(|| oracle.execute_batch(&batch));
    assert_eq!(ys_engine, ys_oracle, "engine must match the interpreter oracle");
    println!(
        "\nexec::BatchEngine on the FS graph: 512 samples in {:.2} ms \
         (naive interpreter: {:.2} ms, {:.1}x) — identical outputs",
        engine_secs * 1e3,
        oracle_secs * 1e3,
        oracle_secs / engine_secs.max(1e-12)
    );
}
