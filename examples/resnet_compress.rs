//! Table-I style compression of the residual CNN (scaled; see DESIGN.md
//! Substitutions), reworked through the full-network compression path.
//!
//!     cargo run --release --example resnet_compress -- --steps 120
//!
//! Trains the residual CNN through the AOT artifacts with FK-grouped
//! group-lasso, then packs every 3×3 conv layer into one multi-layer
//! `NetworkCheckpoint` — layer k's weight is the co × (ci·kh·kw)
//! horizontal concat of its per-input-channel FK matrices — and runs
//! the whole inventory through ONE per-layer recipe: prune + LCC
//! globally, with LCC-only overrides for the stride-2 downsampling
//! layers. Every compressed layer is self-checked bit-exact against its
//! own `NaiveExecutor` oracle, and the aggregated `NetworkReport` is
//! the per-layer adder accounting behind Table I (the bench
//! `table1_resnet` prints the aggregated table).

use anyhow::Result;
use lccnn::compress::{
    Activation, LccSpec, NetworkCheckpoint, NetworkLayer, NetworkPipeline, PruneSpec, Recipe,
    StageSpec,
};
use lccnn::config::ResnetPipelineConfig;
use lccnn::convert::fk_matrices;
use lccnn::data::synth_tiny;
use lccnn::exec::{Executor, NaiveExecutor};
use lccnn::nn::resnet::init_params;
use lccnn::pipeline::resnet::conv_specs;
use lccnn::runtime::Runtime;
use lccnn::tensor::{Matrix, Tensor4};
use lccnn::train::{ConvGrouping, LrSchedule, ResnetTrainer};
use lccnn::util::Rng;

fn main() -> Result<()> {
    lccnn::util::logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = ResnetPipelineConfig { train_steps: 120, ..Default::default() };
    let mut i = 0;
    while i + 1 < args.len() {
        match args[i].as_str() {
            "--steps" => cfg.train_steps = args[i + 1].parse()?,
            "--lambda" => cfg.lambda = args[i + 1].parse()?,
            other => anyhow::bail!("unknown flag {other}"),
        }
        i += 2;
    }

    let rt = Runtime::open_default()?;
    let train_data = synth_tiny::generate(cfg.train_examples, cfg.seed);
    let test_data = synth_tiny::generate(cfg.test_examples, cfg.seed + 1);

    println!(
        "training residual CNN ({} steps, lambda={}, FK grouping)...",
        cfg.train_steps, cfg.lambda
    );
    let mut tr = ResnetTrainer::new(&rt, &init_params(cfg.seed), ConvGrouping::Fk)?;
    tr.lambda = cfg.lambda;
    let sched = LrSchedule { base: cfg.lr, every: 100, factor: 0.9 };
    let curve = tr.train(&train_data, cfg.train_steps, sched, 20, cfg.seed + 1)?;
    for (s, l) in &curve {
        println!("  step {s:>4}  loss {l:.4}");
    }
    let (_, acc) = tr.evaluate(&test_data)?;
    println!("regularized accuracy: {:.1} %\n", acc * 100.0);

    // pack the 3x3 conv inventory into one multi-layer checkpoint:
    // layer k's weight is the co x (ci*kh*kw) horizontal concat of its
    // per-input-channel FK matrices. The layers don't chain dimensionally
    // (NetworkCheckpoint doesn't require it) — each is compressed and
    // oracle-checked on its own through the shared per-layer recipe.
    let store = tr.params_store();
    let specs = conv_specs();
    let mut layers = Vec::with_capacity(specs.len());
    for (name, _, _) in &specs {
        let arr = store.get(name).unwrap();
        let s = &arr.shape;
        let k = Tensor4::from_vec(s[0], s[1], s[2], s[3], arr.data.clone());
        let mats = fk_matrices(&k);
        let (co, kk) = (mats[0].rows(), mats[0].cols());
        let mut w = Matrix::zeros(co, mats.len() * kk);
        for (c, m) in mats.iter().enumerate() {
            for r in 0..co {
                w.row_mut(r)[c * kk..(c + 1) * kk].copy_from_slice(m.row(r));
            }
        }
        layers.push(NetworkLayer { weight: w, bias: None, activation: Activation::Identity });
    }
    let ckpt = NetworkCheckpoint::new(layers)?;

    // one recipe steers the whole inventory: prune + LCC globally (no
    // sharing — clustering trained kernels collapses learned features),
    // with LCC-only overrides for the stride-2 downsampling layers
    let mut recipe = Recipe {
        stages: vec![StageSpec::Prune(PruneSpec::default()), StageSpec::Lcc(LccSpec::default())],
        ..Recipe::default()
    };
    for (idx, (_, _, stride)) in specs.iter().enumerate() {
        if *stride == 2 {
            recipe.layers.entry(idx + 1).or_default().stages = Some(vec!["lcc".to_string()]);
        }
    }
    let net = NetworkPipeline::from_recipe(&recipe)?.run(&ckpt)?;
    println!("{}", net.report().render());

    // per-layer oracle self-check: each compressed layer's batch-major
    // engine vs a NaiveExecutor run of its own adder graph (dense math
    // for layers a recipe override left pre-LCC)
    let mut rng = Rng::new(cfg.seed + 99);
    for (k, layer) in net.layers().iter().enumerate() {
        let model = layer.model();
        let exec = model.executor();
        let oracle = model.lcc().map(|s| NaiveExecutor::new(s.graph().clone()));
        for _ in 0..4 {
            let x = rng.normal_vec(exec.num_inputs(), 1.0);
            let got = exec.execute_one(&x);
            let xk: Vec<f32> = model.kept().iter().map(|&i| x[i]).collect();
            let want = match (&oracle, model.lcc()) {
                (Some(o), Some(slcc)) => o.execute_one(&slcc.layer.segment_sums(&xk)),
                _ => match model.state().shared() {
                    Some(sh) => sh.apply(&xk),
                    None => model.state().dense().matvec(&xk),
                },
            };
            anyhow::ensure!(
                got == want,
                "layer {} ({}) diverged from its oracle",
                k + 1,
                specs[k].0
            );
        }
    }
    println!("oracle self-check: every layer bit-identical to its NaiveExecutor oracle");
    println!("run `cargo bench --bench table1_resnet` for the full Table-I reproduction");
    Ok(())
}
