//! Table-I style compression of the residual CNN (scaled; see DESIGN.md
//! Substitutions).
//!
//!     cargo run --release --example resnet_compress -- --steps 120
//!
//! Trains the residual CNN through the AOT artifacts with FK-grouped
//! group-lasso, then decomposes every 3×3 conv layer with both LCC
//! algorithms under both kernel representations and prints the adder
//! accounting per layer — the per-layer view behind Table I (the bench
//! `table1_resnet` prints the aggregated table).

use anyhow::Result;
use lccnn::config::ResnetPipelineConfig;
use lccnn::data::synth_tiny;
use lccnn::lcc::{decompose, LccConfig};
use lccnn::nn::resnet::init_params;
use lccnn::pipeline::resnet::{conv_layer_additions, conv_specs, ConvRepr};
use lccnn::quant::{matrix_csd_adders, FixedPointFormat};
use lccnn::report::{ratio, Table};
use lccnn::runtime::Runtime;
use lccnn::tensor::Tensor4;
use lccnn::train::{ConvGrouping, LrSchedule, ResnetTrainer};

fn main() -> Result<()> {
    lccnn::util::logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = ResnetPipelineConfig { train_steps: 120, ..Default::default() };
    let mut i = 0;
    while i + 1 < args.len() {
        match args[i].as_str() {
            "--steps" => cfg.train_steps = args[i + 1].parse()?,
            "--lambda" => cfg.lambda = args[i + 1].parse()?,
            other => anyhow::bail!("unknown flag {other}"),
        }
        i += 2;
    }

    let rt = Runtime::open_default()?;
    let train_data = synth_tiny::generate(cfg.train_examples, cfg.seed);
    let test_data = synth_tiny::generate(cfg.test_examples, cfg.seed + 1);

    println!(
        "training residual CNN ({} steps, lambda={}, FK grouping)...",
        cfg.train_steps, cfg.lambda
    );
    let mut tr = ResnetTrainer::new(&rt, &init_params(cfg.seed), ConvGrouping::Fk)?;
    tr.lambda = cfg.lambda;
    let sched = LrSchedule { base: cfg.lr, every: 100, factor: 0.9 };
    let curve = tr.train(&train_data, cfg.train_steps, sched, 20, cfg.seed + 1)?;
    for (s, l) in &curve {
        println!("  step {s:>4}  loss {l:.4}");
    }
    let (_, acc) = tr.evaluate(&test_data)?;
    println!("regularized accuracy: {:.1} %\n", acc * 100.0);

    let store = tr.params_store();
    let fmt = FixedPointFormat::default_weights();
    let mut t = Table::new(
        "per-layer adder accounting (CSD baseline vs LCC, FK and PK)",
        &["layer", "csd-FK", "FP-FK", "FS-FK", "csd-PK", "FS-PK", "FS-FK ratio"],
    );
    for (name, side, stride) in conv_specs() {
        let arr = store.get(&name).unwrap();
        let k = Tensor4::from_vec(
            arr.shape[0],
            arr.shape[1],
            arr.shape[2],
            arr.shape[3],
            arr.data.clone(),
        );
        let mut csd_cost = |m: &lccnn::tensor::Matrix| matrix_csd_adders(m, fmt);
        let csd_fk = conv_layer_additions(&k, side, stride, ConvRepr::Fk, &mut csd_cost);
        let csd_pk = conv_layer_additions(&k, side, stride, ConvRepr::Pk, &mut csd_cost);
        let mut fp_cost = |m: &lccnn::tensor::Matrix| {
            if m.nnz() == 0 { 0 } else { decompose(m, &LccConfig::fp()).additions() }
        };
        let mut fs_cost = |m: &lccnn::tensor::Matrix| {
            if m.nnz() == 0 { 0 } else { decompose(m, &LccConfig::fs()).additions() }
        };
        let fp_fk = conv_layer_additions(&k, side, stride, ConvRepr::Fk, &mut fp_cost);
        let fs_fk = conv_layer_additions(&k, side, stride, ConvRepr::Fk, &mut fs_cost);
        let fs_pk = conv_layer_additions(&k, side, stride, ConvRepr::Pk, &mut fs_cost);
        t.add_row(vec![
            name.clone(),
            csd_fk.to_string(),
            fp_fk.to_string(),
            fs_fk.to_string(),
            csd_pk.to_string(),
            fs_pk.to_string(),
            ratio(csd_fk, fs_fk),
        ]);
    }
    println!("{}", t.render());
    println!("run `cargo bench --bench table1_resnet` for the full Table-I reproduction");
    Ok(())
}
