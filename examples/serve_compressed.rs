//! Serving the compressed model: dynamic batching over the shift-add VM
//! vs the dense PJRT executable — the deployment scenario the paper
//! motivates (Sec. I, FPGA inference in datacenters).
//!
//!     cargo run --release --example serve_compressed
//!
//! Builds a compressed MLP (prune + share + LCC on synthetic trained
//! weights — no training needed for this demo), serves a Poisson-ish
//! request stream through both backends, and reports latency /
//! throughput / batch-size statistics.

use anyhow::Result;
use lccnn::cluster::affinity::{cluster_columns, AffinityParams};
use lccnn::config::{ExecConfig, ServeConfig};
use lccnn::lcc::LccConfig;
use lccnn::nn::compressed::{CompressedMlp, Layer1};
use lccnn::nn::mlp::MlpParams;
use lccnn::pipeline::mlp::synthetic_reg_weights;
use lccnn::prune::compact_columns;
use lccnn::runtime::{HostTensor, PjrtService};
use lccnn::serve::{BatchEvaluator, CompressedMlpBackend, PjrtMlpBackend, Server};
use lccnn::share::SharedLayer;
use lccnn::util::Rng;
use std::sync::Arc;
use std::time::Instant;

fn build_compressed(params: &MlpParams) -> CompressedMlp {
    // synthetic "trained" regularized weights: ~120 active columns in
    // correlated groups, so pruning + sharing + LCC all engage
    let w1 = synthetic_reg_weights(0, 120);
    let compact = compact_columns(&w1, 1e-6);
    let clustering = cluster_columns(&compact.weights, &AffinityParams::default());
    let shared = SharedLayer::from_clustering(&compact.weights, &clustering);
    // batch-major exec engine tuning; override per field with the
    // LCCNN_EXEC_* env vars (see ExecConfig::from_env)
    let exec_cfg = ExecConfig::from_env();
    let slcc = shared.with_lcc_exec(&LccConfig::fs(), exec_cfg);
    println!(
        "compressed model: {} active inputs -> {} clusters, LCC graph {} adds",
        compact.kept.len(),
        clustering.num_clusters(),
        slcc.additions()
    );
    println!("exec engine: {exec_cfg:?}");
    CompressedMlp {
        kept: compact.kept,
        layer1: Layer1::SharedLcc(slcc),
        b1: params.b1.clone(),
        w2: params.w2.clone(),
        b2: params.b2.clone(),
    }
}

fn drive(server: &Server, n_requests: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let start = Instant::now();
    // bursts of 8 concurrent requests to give the batcher work
    let mut done = 0usize;
    while done < n_requests {
        let burst = 8.min(n_requests - done);
        let rxs: Vec<_> = (0..burst)
            .map(|_| server.submit(rng.normal_vec(784, 1.0)))
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        done += burst;
    }
    n_requests as f64 / start.elapsed().as_secs_f64()
}

fn main() -> Result<()> {
    lccnn::util::logger::init();
    let params = MlpParams::init(0);
    let n_requests = 2000;

    // --- compressed backend (shift-add VM) ------------------------------
    let model = Arc::new(build_compressed(&params));
    let backend: Arc<dyn BatchEvaluator> = Arc::new(CompressedMlpBackend { model });
    let server = Server::start(backend, ServeConfig::default());
    let thpt = drive(&server, n_requests, 1);
    let stats = server.shutdown();
    println!("\n[compressed-exec] {:>7.0} req/s  p50 {:>7.1} us  p99 {:>7.1} us  mean batch {:.1}",
        thpt, stats.p50_latency_us, stats.p99_latency_us, stats.mean_batch_size);

    // --- dense PJRT backend ---------------------------------------------
    match PjrtService::start_default() {
        Ok(service) => {
            let host_params = vec![
                HostTensor::F32(vec![300, 784], params.w1.data().to_vec()),
                HostTensor::F32(vec![300], params.b1.clone()),
                HostTensor::F32(vec![10, 300], params.w2.data().to_vec()),
                HostTensor::F32(vec![10], params.b2.clone()),
            ];
            let backend: Arc<dyn BatchEvaluator> =
                Arc::new(PjrtMlpBackend::new(Arc::new(service), host_params, 32));
            let server = Server::start(backend, ServeConfig::default());
            let thpt = drive(&server, n_requests, 2);
            let stats = server.shutdown();
            println!(
                "[dense-pjrt]     {:>8.0} req/s  p50 {:>7.1} us  p99 {:>7.1} us  mean batch {:.1}",
                thpt, stats.p50_latency_us, stats.p99_latency_us, stats.mean_batch_size
            );
        }
        Err(e) => println!("[dense-pjrt] skipped (artifacts not built?): {e:#}"),
    }

    println!("\nnote: on this host both run on the same CPU; the point of the");
    println!("comparison is the *addition count* the VM executes (the FPGA cost");
    println!("model), plus a working dynamic-batching serving layer over both.");
    Ok(())
}
