//! The unified compression pipeline, end to end:
//!
//!     cargo run --release --example compress_pipeline
//!
//! One `Recipe` drives prune → share → LCC on synthetic
//! post-regularization weights, prints the per-stage
//! `CompressionReport`, and self-checks the servable executor against
//! the `NaiveExecutor` oracle *and* against the legacy hand-wired stage
//! composition — bit-identical, or the example exits nonzero.

use anyhow::{bail, Result};
use lccnn::cluster::affinity::{cluster_columns, AffinityParams};
use lccnn::compress::{demo_weights, Pipeline, Recipe};
use lccnn::config::ExecConfig;
use lccnn::exec::{Executor, NaiveExecutor};
use lccnn::lcc::LccConfig;
use lccnn::metrics::Metrics;
use lccnn::prune::compact_columns;
use lccnn::share::SharedLayer;
use lccnn::util::Rng;

fn main() -> Result<()> {
    lccnn::util::logger::init();

    // synthetic "post-regularization" weights: correlated column groups
    // plus exactly-zero pruned columns, so every stage engages
    let w = demo_weights(32, 5, 4, 42);
    println!("input weights: {}x{}", w.rows(), w.cols());

    // one declarative recipe from raw weights to served engine; the
    // exact same run is reproducible from its TOML form
    let recipe = Recipe { exec: ExecConfig::serial(), ..Recipe::default() };
    println!("\nrecipe:\n{}", recipe.to_toml_string());

    let metrics = Metrics::new();
    let model = Pipeline::from_recipe(&recipe)?.run_with_metrics(&w, &metrics)?;
    println!("{}", model.report().render());

    // --- self-check 1: executor vs the oracle-composed reference ---------
    let exec = model.executor();
    let slcc = model.lcc().expect("recipe ends in lcc");
    let oracle = NaiveExecutor::new(slcc.graph().clone());
    let mut rng = Rng::new(7);
    let mut mismatches = 0usize;
    let xs: Vec<Vec<f32>> = (0..64).map(|_| rng.normal_vec(w.cols(), 1.0)).collect();
    for (x, y) in xs.iter().zip(exec.execute_batch(&xs)) {
        let xk: Vec<f32> = model.kept().iter().map(|&i| x[i]).collect();
        let want = oracle.execute_one(&slcc.layer.segment_sums(&xk));
        if y != want {
            eprintln!("oracle mismatch: {y:?} != {want:?}");
            mismatches += 1;
        }
    }

    // --- self-check 2: bit-identical to the legacy hand-wired stages -----
    let compact = compact_columns(&w, 1e-6);
    let clustering = cluster_columns(&compact.weights, &AffinityParams::default());
    let legacy = SharedLayer::from_clustering(&compact.weights, &clustering)
        .with_lcc_exec(&LccConfig::fs(), ExecConfig::serial());
    for x in &xs {
        let xk: Vec<f32> = compact.kept.iter().map(|&i| x[i]).collect();
        if exec.execute_one(x) != legacy.apply(&xk) {
            eprintln!("legacy-path mismatch on {x:?}");
            mismatches += 1;
        }
    }

    println!("{}", metrics.render());
    if mismatches > 0 {
        bail!("{mismatches} mismatches against the oracle / legacy path");
    }
    println!(
        "verified: {} requests bit-identical to the oracle and the legacy stage wiring \
         ({:.1}x compression, rel err {:.2e})",
        2 * xs.len(),
        model.report().final_ratio(),
        model.report().final_rel_err()
    );
    Ok(())
}
